//! Minimal ASCII plotting for figure regeneration (offline build: no
//! plotting crates). Line charts for sweeps (Fig. 6) and horizontal
//! stacked bars for per-layer allocations (Fig. 7).

/// Render one or more `(label, points)` series as an ASCII line chart.
/// Points are `(x, y)`; `None` y-values (infeasible points) leave gaps.
/// Each series draws with its own glyph (`*`, `o`, `+`, `x`).
pub fn line_chart(
    title: &str,
    series: &[(&str, Vec<(f64, Option<f64>)>)],
    width: usize,
    height: usize,
) -> String {
    const GLYPHS: [char; 4] = ['*', 'o', '+', 'x'];
    let width = width.max(16);
    let height = height.max(4);

    let xs: Vec<f64> = series.iter().flat_map(|(_, pts)| pts.iter().map(|p| p.0)).collect();
    let ys: Vec<f64> =
        series.iter().flat_map(|(_, pts)| pts.iter().filter_map(|p| p.1)).collect();
    if xs.is_empty() || ys.is_empty() {
        return format!("{title}\n(no data)\n");
    }
    let (x0, x1) = (xs.iter().cloned().fold(f64::INFINITY, f64::min), xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max));
    let (y0, y1) = (0.0_f64.min(ys.iter().cloned().fold(f64::INFINITY, f64::min)), ys.iter().cloned().fold(f64::NEG_INFINITY, f64::max));
    let xspan = (x1 - x0).max(1e-12);
    let yspan = (y1 - y0).max(1e-12);

    let mut grid = vec![vec![' '; width]; height];
    for (si, (_, pts)) in series.iter().enumerate() {
        let glyph = GLYPHS[si % GLYPHS.len()];
        for &(x, y) in pts {
            let Some(y) = y else { continue };
            let col = (((x - x0) / xspan) * (width as f64 - 1.0)).round() as usize;
            let row = (((y - y0) / yspan) * (height as f64 - 1.0)).round() as usize;
            let row = height - 1 - row.min(height - 1);
            grid[row][col.min(width - 1)] = glyph;
        }
    }

    let mut out = String::new();
    out.push_str(title);
    out.push('\n');
    let legend: Vec<String> = series
        .iter()
        .enumerate()
        .map(|(i, (label, _))| format!("{}={}", GLYPHS[i % GLYPHS.len()], label))
        .collect();
    out.push_str(&format!("  legend: {}\n", legend.join("  ")));
    for (i, row) in grid.iter().enumerate() {
        let y_label = if i == 0 {
            format!("{y1:>8.1}")
        } else if i == height - 1 {
            format!("{y0:>8.1}")
        } else {
            " ".repeat(8)
        };
        out.push_str(&format!("{y_label} |{}|\n", row.iter().collect::<String>()));
    }
    out.push_str(&format!(
        "{:>8}  {:<w$.2}{:>r$.2}\n",
        "",
        x0,
        x1,
        w = width / 2,
        r = width - width / 2
    ));
    out
}

/// Render a two-part horizontal stacked bar chart: each row shows
/// `left` (e.g. on-chip KB, glyph `#`) then `right` (off-chip KB, glyph
/// `~`), scaled jointly so the longest total bar spans `width` chars.
pub fn stacked_bars(
    title: &str,
    rows: &[(String, f64, f64)],
    width: usize,
    unit: &str,
) -> String {
    let width = width.max(16);
    let max_total =
        rows.iter().map(|(_, a, b)| a + b).fold(0.0_f64, f64::max).max(1e-12);
    let mut out = String::new();
    out.push_str(title);
    out.push('\n');
    out.push_str(&format!("  (#=on-chip  ~=off-chip, bar = {max_total:.1} {unit} max)\n"));
    let name_w = rows.iter().map(|(n, _, _)| n.len()).max().unwrap_or(8).min(26);
    for (name, a, b) in rows {
        let la = ((a / max_total) * width as f64).round() as usize;
        let lb = ((b / max_total) * width as f64).round() as usize;
        out.push_str(&format!(
            "  {:<name_w$} |{}{}{}  {:.1}+{:.1}\n",
            &name[..name.len().min(name_w)],
            "#".repeat(la),
            "~".repeat(lb),
            " ".repeat(width.saturating_sub(la + lb)),
            a,
            b,
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn line_chart_renders_extremes() {
        let pts: Vec<(f64, Option<f64>)> =
            (0..10).map(|i| (i as f64, Some(i as f64 * 2.0))).collect();
        let c = line_chart("test", &[("up", pts)], 40, 8);
        assert!(c.contains("test"));
        assert!(c.contains("18.0")); // y max label
        assert!(c.contains('*'));
        assert_eq!(c.lines().count(), 2 + 8 + 1);
    }

    #[test]
    fn line_chart_gaps_for_infeasible() {
        let pts = vec![(0.0, None), (1.0, Some(5.0))];
        let c = line_chart("gaps", &[("s", pts)], 20, 4);
        // only one plotted point in the grid (exclude the legend line)
        let grid_stars: usize =
            c.lines().filter(|l| l.contains('|')).map(|l| l.matches('*').count()).sum();
        assert_eq!(grid_stars, 1);
    }

    #[test]
    fn line_chart_multi_series_glyphs() {
        let a: Vec<_> = (0..5).map(|i| (i as f64, Some(1.0))).collect();
        let b: Vec<_> = (0..5).map(|i| (i as f64, Some(2.0))).collect();
        let c = line_chart("two", &[("a", a), ("b", b)], 30, 6);
        assert!(c.contains('*') && c.contains('o'));
        assert!(c.contains("*=a") && c.contains("o=b"));
    }

    #[test]
    fn empty_chart_degrades() {
        let c = line_chart("none", &[("s", vec![])], 30, 6);
        assert!(c.contains("no data"));
    }

    #[test]
    fn stacked_bars_scale_jointly() {
        let rows = vec![
            ("layer1".to_string(), 10.0, 0.0),
            ("layer2".to_string(), 5.0, 5.0),
            ("layer3".to_string(), 0.0, 20.0),
        ];
        let c = stacked_bars("alloc", &rows, 20, "KB");
        assert!(c.contains("layer3"));
        // layer3 is all off-chip: 20 tildes at full width
        let l3 = c.lines().find(|l| l.contains("layer3")).unwrap();
        assert_eq!(l3.matches('~').count(), 20);
        assert_eq!(l3.matches('#').count(), 0);
        let l1 = c.lines().find(|l| l.contains("layer1")).unwrap();
        assert_eq!(l1.matches('#').count(), 10);
    }
}
