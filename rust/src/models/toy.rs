//! Toy CNN used by the end-to-end serving example: its architecture mirrors
//! `python/compile/model.py` exactly, so the Rust-side schedule (this IR run
//! through the DSE + simulator) and the PJRT-side numerics (the AOT-lowered
//! JAX model) describe the same network.
//!
//! KEEP IN SYNC with `python/compile/model.py::ToyCnnSpec`.

use crate::ir::{Layer, Network, OpKind, Quant};

/// 4-layer CNN for 32x32x3 input (CIFAR-like): three 3x3 convolutions, a
/// global average pool, and a 10-way classifier. ~93k parameters.
pub fn toy_cnn(q: Quant) -> Network {
    let mut n = Network::new("toy_cnn", (3, 32, 32), q);
    n.push(Layer::conv("conv1", 3, 16, 32, 32, 3, 1, 1, q));
    n.push(Layer::conv("conv2", 16, 32, 32, 32, 3, 2, 1, q));
    n.push(Layer::conv("conv3", 32, 64, 16, 16, 3, 2, 1, q));
    n.push(Layer {
        name: "gap".into(),
        op: OpKind::GlobalAvgPool,
        c_in: 64,
        c_out: 64,
        h_in: 8,
        w_in: 8,
        quant: q,
        skip_from: None,
    });
    n.push(Layer::fc("fc", 64, 10, q));
    n
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_chain() {
        let n = toy_cnn(Quant::W8A8);
        assert_eq!(n.layers.len(), 5);
        assert_eq!(n.layers.last().unwrap().c_out, 10);
    }

    #[test]
    fn param_count_stable() {
        // conv1 3*16*9 + conv2 16*32*9 + conv3 32*64*9 + fc 64*10
        let expect = 432 + 4608 + 18432 + 640;
        assert_eq!(toy_cnn(Quant::W8A8).stats().params, expect);
    }
}
