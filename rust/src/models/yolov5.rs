//! YOLOv5n (v6.0 architecture, 640x640 COCO input) — paper §V-D.
//!
//! The CSP bottlenecks and PAN head are expanded into their constituent
//! convolutions in pipeline order. Channel-concatenation merge points are
//! modeled as stream-merge (EltwiseAdd-kind) stages — on hardware both are a
//! bypass FIFO plus a merge node, and neither carries weights, so the
//! DSE/scheduling behaviour is identical. Total ~1.9M parameters.

use crate::ir::{Layer, Network, OpKind, PoolKind, Quant};

fn merge(name: &str, c_in: u32, c_out: u32, hw: u32, skip: usize, q: Quant) -> Layer {
    Layer {
        name: name.into(),
        op: OpKind::EltwiseAdd,
        c_in,
        c_out,
        h_in: hw,
        w_in: hw,
        quant: q,
        skip_from: Some(skip),
    }
}

/// One C3 block: cv1/cv2 1x1 halves, `n` bottlenecks (1x1 + 3x3), cv3 1x1.
fn c3(net: &mut Network, name: &str, c: u32, hw: u32, n_bn: u32, shortcut: bool, q: Quant) {
    let h = c / 2;
    let entry = net.layers.len() - 1;
    net.push_unchecked(Layer::conv(format!("{name}.cv1"), c, h, hw, hw, 1, 1, 0, q));
    for b in 0..n_bn {
        let bin = net.layers.len() - 1;
        net.push_unchecked(Layer::conv(format!("{name}.m{b}.cv1"), h, h, hw, hw, 1, 1, 0, q));
        net.push_unchecked(Layer::conv(format!("{name}.m{b}.cv2"), h, h, hw, hw, 3, 1, 1, q));
        if shortcut {
            net.push_unchecked(merge(&format!("{name}.m{b}.add"), h, h, hw, bin, q));
        }
    }
    // cv2 runs on the block input in parallel with the bottleneck chain
    net.push_unchecked(Layer::conv(format!("{name}.cv2"), c, h, hw, hw, 1, 1, 0, q));
    net.push_unchecked(merge(&format!("{name}.cat"), h, c, hw, entry, q));
    net.push_unchecked(Layer::conv(format!("{name}.cv3"), c, c, hw, hw, 1, 1, 0, q));
}

/// YOLOv5n: depth multiple 0.33, width multiple 0.25 of YOLOv5l.
pub fn yolov5n(q: Quant) -> Network {
    let mut n = Network::new("yolov5n", (3, 640, 640), q);

    // --- backbone ---
    n.push(Layer::conv("stem", 3, 16, 640, 640, 6, 2, 2, q)); // P1 320
    n.push(Layer::conv("conv1", 16, 32, 320, 320, 3, 2, 1, q)); // P2 160
    c3(&mut n, "c3_1", 32, 160, 1, true, q);
    n.push_unchecked(Layer::conv("conv2", 32, 64, 160, 160, 3, 2, 1, q)); // P3 80
    c3(&mut n, "c3_2", 64, 80, 2, true, q);
    let p3 = n.layers.len() - 1;
    n.push_unchecked(Layer::conv("conv3", 64, 128, 80, 80, 3, 2, 1, q)); // P4 40
    c3(&mut n, "c3_3", 128, 40, 3, true, q);
    let p4 = n.layers.len() - 1;
    n.push_unchecked(Layer::conv("conv4", 128, 256, 40, 40, 3, 2, 1, q)); // P5 20
    c3(&mut n, "c3_4", 256, 20, 1, true, q);
    // SPPF: cv1, 3x maxpool5, cv2
    n.push_unchecked(Layer::conv("sppf.cv1", 256, 128, 20, 20, 1, 1, 0, q));
    for i in 0..3 {
        n.push_unchecked(Layer {
            name: format!("sppf.pool{i}"),
            op: OpKind::Pool { kernel: 5, stride: 1, pad: 2, kind: PoolKind::Max },
            c_in: 128,
            c_out: 128,
            h_in: 20,
            w_in: 20,
            quant: q,
            skip_from: None,
        });
    }
    n.push_unchecked(Layer::conv("sppf.cv2", 512, 256, 20, 20, 1, 1, 0, q));

    // --- PAN head ---
    n.push_unchecked(Layer::conv("head.conv1", 256, 128, 20, 20, 1, 1, 0, q));
    let h_p5 = n.layers.len() - 1;
    // upsample to 40, concat with P4
    n.push_unchecked(merge("head.cat1", 128, 256, 40, p4, q));
    c3(&mut n, "head.c3_1", 256, 40, 1, false, q);
    n.push_unchecked(Layer::conv("head.conv2", 256, 64, 40, 40, 1, 1, 0, q));
    let h_p4 = n.layers.len() - 1;
    // upsample to 80, concat with P3
    n.push_unchecked(merge("head.cat2", 64, 128, 80, p3, q));
    c3(&mut n, "head.c3_2", 128, 80, 1, false, q);
    let out_p3 = n.layers.len() - 1;
    // down path
    n.push_unchecked(Layer::conv("head.conv3", 128, 64, 80, 80, 3, 2, 1, q));
    n.push_unchecked(merge("head.cat3", 64, 128, 40, h_p4, q));
    c3(&mut n, "head.c3_3", 128, 40, 1, false, q);
    let out_p4 = n.layers.len() - 1;
    n.push_unchecked(Layer::conv("head.conv4", 128, 128, 40, 40, 3, 2, 1, q));
    n.push_unchecked(merge("head.cat4", 128, 256, 20, h_p5, q));
    c3(&mut n, "head.c3_4", 256, 20, 1, false, q);

    // --- detect convs: 3 scales x (nc+5)*3 = 255 outputs ---
    n.push_unchecked(Layer::conv("detect.p5", 256, 255, 20, 20, 1, 1, 0, q));
    n.push_unchecked(Layer::conv("detect.p4", 128, 255, 40, 40, 1, 1, 0, q));
    let _ = out_p4;
    n.push_unchecked(Layer::conv("detect.p3", 128, 255, 80, 80, 1, 1, 0, q));
    let _ = out_p3;
    n
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn params_near_1_9m() {
        let p = yolov5n(Quant::W8A8).stats().params;
        assert!((1_500_000..2_300_000).contains(&p), "{p}");
    }

    #[test]
    fn macs_near_2_2g() {
        // YOLOv5n @640: ~4.5 GFLOPs => ~2.2 GMACs. Our chain expansion of the
        // CSP blocks lands slightly above (stream-merge stages double-count
        // some half-width paths); same decade is what matters for the DSE.
        let m = yolov5n(Quant::W8A8).stats().macs;
        assert!((1_600_000_000..3_300_000_000).contains(&m), "{m}");
    }

    #[test]
    fn three_detect_heads() {
        let n = yolov5n(Quant::W8A8);
        let det: Vec<_> =
            n.layers.iter().filter(|l| l.name.starts_with("detect.")).collect();
        assert_eq!(det.len(), 3);
        assert!(det.iter().all(|l| l.c_out == 255));
    }

    #[test]
    fn merges_reference_backwards() {
        let n = yolov5n(Quant::W8A8);
        for (i, l) in n.layers.iter().enumerate() {
            if let Some(s) = l.skip_from {
                assert!(s < i, "layer {i} `{}` skips forward", l.name);
            }
        }
    }
}
