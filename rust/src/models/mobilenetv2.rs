//! MobileNetV2 (Sandler et al., 2018) for 224x224 ImageNet input.

use crate::ir::{Layer, Network, OpKind, Quant};

/// Inverted-residual block configuration table `(t, c, n, s)` from the paper.
const BLOCKS: [(u32, u32, u32, u32); 7] = [
    (1, 16, 1, 1),
    (6, 24, 2, 2),
    (6, 32, 3, 2),
    (6, 64, 4, 2),
    (6, 96, 3, 1),
    (6, 160, 3, 2),
    (6, 320, 1, 1),
];

/// MobileNetV2: stem conv + 17 inverted residual blocks + 1x1 head + fc.
/// ~3.5M parameters (paper Table I).
pub fn mobilenet_v2(q: Quant) -> Network {
    let mut n = Network::new("mobilenetv2", (3, 224, 224), q);
    n.push(Layer::conv("stem", 3, 32, 224, 224, 3, 2, 1, q));

    let mut c_in = 32u32;
    let mut hw = 112u32;
    let mut bi = 0;
    for &(t, c, blocks, s) in BLOCKS.iter() {
        for b in 0..blocks {
            let stride = if b == 0 { s } else { 1 };
            let hidden = c_in * t;
            let residual = stride == 1 && c_in == c;
            let block_in = n.layers.len() - 1;
            if t != 1 {
                n.push(Layer::conv(
                    format!("block{bi}.expand"),
                    c_in, hidden, hw, hw, 1, 1, 0, q,
                ));
            }
            n.push(Layer::depthwise(
                format!("block{bi}.dw"),
                hidden, hw, hw, 3, stride, 1, q,
            ));
            let hw_out = if stride == 2 { hw / 2 } else { hw };
            n.push(Layer::conv(
                format!("block{bi}.project"),
                hidden, c, hw_out, hw_out, 1, 1, 0, q,
            ));
            if residual {
                n.push_unchecked(Layer {
                    name: format!("block{bi}.add"),
                    op: OpKind::EltwiseAdd,
                    c_in: c,
                    c_out: c,
                    h_in: hw_out,
                    w_in: hw_out,
                    quant: q,
                    skip_from: Some(block_in),
                });
            }
            c_in = c;
            hw = hw_out;
            bi += 1;
        }
    }

    n.push(Layer::conv("head", 320, 1280, 7, 7, 1, 1, 0, q));
    n.push(Layer {
        name: "avgpool".into(),
        op: OpKind::GlobalAvgPool,
        c_in: 1280,
        c_out: 1280,
        h_in: 7,
        w_in: 7,
        quant: q,
        skip_from: None,
    });
    n.push(Layer::fc("classifier", 1280, 1000, q));
    n
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seventeen_blocks() {
        let n = mobilenet_v2(Quant::W4A4);
        let dw = n.layers.iter().filter(|l| {
            matches!(l.op, OpKind::Conv { groups, .. } if groups > 1)
        }).count();
        assert_eq!(dw, 17, "one depthwise conv per inverted-residual block");
    }

    #[test]
    fn params_close_to_3_5m() {
        let p = mobilenet_v2(Quant::W8A8).stats().params;
        assert!((3_300_000..3_700_000).contains(&p), "{p}");
    }

    #[test]
    fn macs_close_to_0_3g() {
        let m = mobilenet_v2(Quant::W8A8).stats().macs;
        assert!((270_000_000..340_000_000).contains(&m), "{m}");
    }

    #[test]
    fn final_spatial_is_7x7() {
        let n = mobilenet_v2(Quant::W8A8);
        let head = n.layers.iter().find(|l| l.name == "head").unwrap();
        assert_eq!((head.h_in, head.w_in), (7, 7));
    }
}
