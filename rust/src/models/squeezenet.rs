//! SqueezeNet 1.1 (Iandola et al., 2016) for 224x224 ImageNet input.
//!
//! Small-parameter architecture (~1.2M params) built from *fire modules*
//! (squeeze 1x1 → parallel expand 1x1 / expand 3x3 → concat). Included in
//! the zoo as the extreme low-parameter point: on every paper device the
//! weights fit on-chip, so it isolates the activation-dominant regime of
//! the pipelined architecture.
//!
//! Chain mapping: the two expand branches run as consecutive CEs with the
//! concat realised as a channel-interleaving FIFO merge, which is timing-
//! neutral in the chain model; the expand-3x3 CE carries the branch merge
//! (`skip_from` on an EltwiseAdd is not used — concat changes channel
//! count, so the merge point is modeled as the wider following layer).

use crate::ir::{Layer, Network, OpKind, PoolKind, Quant};

fn maxpool(name: &str, c: u32, h: u32, w: u32, q: Quant) -> Layer {
    Layer {
        name: name.into(),
        op: OpKind::Pool { kernel: 3, stride: 2, pad: 0, kind: PoolKind::Max },
        c_in: c,
        c_out: c,
        h_in: h,
        w_in: w,
        quant: q,
        skip_from: None,
    }
}

/// One fire module: squeeze s1x1, then expand e1x1 and e3x3 whose outputs
/// concatenate to `e1 + e3` channels.
fn fire(n: &mut Network, name: &str, c_in: u32, s: u32, e1: u32, e3: u32, hw: u32, q: Quant) -> u32 {
    n.push(Layer::conv(format!("{name}.squeeze"), c_in, s, hw, hw, 1, 1, 0, q));
    // expand branches: chained CEs, concat = interleaved FIFO merge
    n.push(Layer::conv(format!("{name}.expand1x1"), s, e1, hw, hw, 1, 1, 0, q));
    n.push_unchecked(Layer::conv(format!("{name}.expand3x3"), s, e3, hw, hw, 3, 1, 1, q));
    // the next consumer sees e1+e3 channels; record the merge as a
    // zero-weight passthrough so chain shapes stay consistent
    n.push_unchecked(Layer {
        name: format!("{name}.concat"),
        op: OpKind::Relu, // pure streaming op: concat costs no compute
        c_in: e1 + e3,
        c_out: e1 + e3,
        h_in: hw,
        w_in: hw,
        quant: q,
        skip_from: None,
    });
    e1 + e3
}

/// SqueezeNet 1.1 (the efficient revision: stride-2 stem, earlier pools).
pub fn squeezenet(q: Quant) -> Network {
    let mut n = Network::new("squeezenet", (3, 224, 224), q);
    n.push(Layer::conv("conv1", 3, 64, 224, 224, 3, 2, 0, q)); // 111x111
    n.push(maxpool("pool1", 64, 111, 111, q)); // 55x55

    let mut c = fire(&mut n, "fire2", 64, 16, 64, 64, 55, q);
    c = fire(&mut n, "fire3", c, 16, 64, 64, 55, q);
    n.push(maxpool("pool3", c, 55, 55, q)); // 27x27

    c = fire(&mut n, "fire4", c, 32, 128, 128, 27, q);
    c = fire(&mut n, "fire5", c, 32, 128, 128, 27, q);
    n.push(maxpool("pool5", c, 27, 27, q)); // 13x13

    c = fire(&mut n, "fire6", c, 48, 192, 192, 13, q);
    c = fire(&mut n, "fire7", c, 48, 192, 192, 13, q);
    c = fire(&mut n, "fire8", c, 64, 256, 256, 13, q);
    c = fire(&mut n, "fire9", c, 64, 256, 256, 13, q);

    // classifier: conv10 1x1 to 1000 classes + GAP
    n.push(Layer::conv("conv10", c, 1000, 13, 13, 1, 1, 0, q));
    n.push(Layer {
        name: "avgpool".into(),
        op: OpKind::GlobalAvgPool,
        c_in: 1000,
        c_out: 1000,
        h_in: 13,
        w_in: 13,
        quant: q,
        skip_from: None,
    });
    n
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn params_about_1_2m() {
        let p = squeezenet(Quant::W8A8).stats().params;
        // reference squeezenet1_1: 1,235,496 params
        assert!((1_150_000..1_300_000).contains(&p), "{p}");
    }

    #[test]
    fn macs_in_published_range() {
        let m = squeezenet(Quant::W8A8).stats().macs;
        // squeezenet1_1 ≈ 0.35 GMACs
        assert!((280_000_000..420_000_000).contains(&m), "{m}");
    }

    #[test]
    fn fire_modules_count() {
        let n = squeezenet(Quant::W8A8);
        let squeezes =
            n.layers.iter().filter(|l| l.name.ends_with(".squeeze")).count();
        assert_eq!(squeezes, 8, "fire2..fire9");
        // 8 fires x 3 convs + conv1 + conv10 = 26 weight layers
        assert_eq!(n.stats().weight_layers, 26);
    }

    #[test]
    fn fits_on_chip_from_zc706_up() {
        // the zoo's raison d'être for this model: ~1.2 MB of W8 weights fit
        // every device from the ZC706 up without streaming (on the Zedboard
        // the W8 variant leaves no BRAM headroom for FIFOs — W4 fits).
        use crate::device::Device;
        use crate::dse::{self, DseConfig};
        let n = squeezenet(Quant::W8A8);
        for dev in Device::all().into_iter().filter(|d| d.name != "zedboard") {
            let r = dse::run(&n, &dev, &DseConfig::vanilla());
            assert!(r.is_some(), "squeezenet vanilla must fit {}", dev.name);
        }
        let w4 = squeezenet(Quant::W4A4);
        assert!(
            dse::run(&w4, &Device::zedboard(), &DseConfig::vanilla()).is_some(),
            "W4 squeezenet must fit the zedboard on-chip"
        );
    }
}
