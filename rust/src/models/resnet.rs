//! ResNet-18 / ResNet-50 (He et al., 2016) for 224x224 ImageNet input.

use crate::ir::{Layer, Network, OpKind, PoolKind, Quant};

fn maxpool(c: u32, h: u32, w: u32, q: Quant) -> Layer {
    Layer {
        name: "maxpool".into(),
        op: OpKind::Pool { kernel: 3, stride: 2, pad: 1, kind: PoolKind::Max },
        c_in: c,
        c_out: c,
        h_in: h,
        w_in: w,
        quant: q,
        skip_from: None,
    }
}

fn gap(c: u32, h: u32, w: u32, q: Quant) -> Layer {
    Layer {
        name: "avgpool".into(),
        op: OpKind::GlobalAvgPool,
        c_in: c,
        c_out: c,
        h_in: h,
        w_in: w,
        quant: q,
        skip_from: None,
    }
}

fn add(c: u32, h: u32, w: u32, skip: usize, q: Quant) -> Layer {
    Layer {
        name: "add".into(),
        op: OpKind::EltwiseAdd,
        c_in: c,
        c_out: c,
        h_in: h,
        w_in: w,
        quant: q,
        skip_from: Some(skip),
    }
}

/// Basic-block ResNet skeleton shared by ResNet-18 (`blocks = [2,2,2,2]`)
/// and ResNet-34 (`blocks = [3,4,6,3]`).
fn basic_resnet(name: &str, blocks: [u32; 4], q: Quant) -> Network {
    let mut n = Network::new(name, (3, 224, 224), q);
    n.push(Layer::conv("conv1", 3, 64, 224, 224, 7, 2, 3, q));
    n.push(maxpool(64, 112, 112, q));

    let stages: [(u32, u32, u32); 4] =
        [(64, 56, 1), (128, 56, 2), (256, 28, 2), (512, 14, 2)];
    let mut c_in = 64u32;
    for (si, &(c, h_in, stride0)) in stages.iter().enumerate() {
        for b in 0..blocks[si] {
            let stride = if b == 0 { stride0 } else { 1 };
            let h = if b == 0 { h_in } else { h_in / stride0 };
            let h_out = h / stride;
            let block_in = n.layers.len() - 1;
            n.push(Layer::conv(
                format!("layer{}.{}.conv1", si + 1, b),
                c_in, c, h, h, 3, stride, 1, q,
            ));
            n.push(Layer::conv(
                format!("layer{}.{}.conv2", si + 1, b),
                c, c, h_out, h_out, 3, 1, 1, q,
            ));
            if b == 0 && (stride0 != 1 || c_in != c) {
                // downsample on the skip path: input is the block input
                n.push_unchecked(Layer::conv(
                    format!("layer{}.{}.downsample", si + 1, b),
                    c_in, c, h, h, 1, stride0, 0, q,
                ));
            }
            n.push_unchecked(add(c, h_out, h_out, block_in, q));
            c_in = c;
        }
    }
    n.push(gap(512, 7, 7, q));
    n.push(Layer::fc("fc", 512, 1000, q));
    n
}

/// ResNet-18: conv1 + 4 stages x 2 basic blocks + fc.
/// 21 weight layers (1 stem + 16 block convs + 3 downsample + 1 fc),
/// 11.7M parameters — matches paper Table I and Fig. 7.
pub fn resnet18(q: Quant) -> Network {
    basic_resnet("resnet18", [2, 2, 2, 2], q)
}

/// ResNet-34: the [3,4,6,3] basic-block variant (21.8M parameters) — not in
/// the paper\'s grid, included to exercise the toolflow between the 18/50
/// memory points.
pub fn resnet34(q: Quant) -> Network {
    basic_resnet("resnet34", [3, 4, 6, 3], q)
}

/// ResNet-50: conv1 + bottleneck stages [3,4,6,3] + fc. 25.6M parameters.
pub fn resnet50(q: Quant) -> Network {
    let mut n = Network::new("resnet50", (3, 224, 224), q);
    n.push(Layer::conv("conv1", 3, 64, 224, 224, 7, 2, 3, q));
    n.push(maxpool(64, 112, 112, q));

    let stages: [(u32, u32, u32, u32); 4] = [
        // (base width, blocks, input spatial, first stride)
        (64, 3, 56, 1),
        (128, 4, 56, 2),
        (256, 6, 28, 2),
        (512, 3, 14, 2),
    ];
    let mut c_in = 64u32;
    for (si, &(width, blocks, h_in, stride0)) in stages.iter().enumerate() {
        let c_out = width * 4;
        for b in 0..blocks {
            let stride = if b == 0 { stride0 } else { 1 };
            let h = if b == 0 { h_in } else { h_in / stride0 };
            let h_out = h / stride;
            let block_in = n.layers.len() - 1;
            n.push(Layer::conv(
                format!("layer{}.{}.conv1", si + 1, b),
                c_in, width, h, h, 1, 1, 0, q,
            ));
            n.push(Layer::conv(
                format!("layer{}.{}.conv2", si + 1, b),
                width, width, h, h, 3, stride, 1, q,
            ));
            n.push(Layer::conv(
                format!("layer{}.{}.conv3", si + 1, b),
                width, c_out, h_out, h_out, 1, 1, 0, q,
            ));
            if b == 0 {
                n.push_unchecked(Layer::conv(
                    format!("layer{}.{}.downsample", si + 1, b),
                    c_in, c_out, h, h, 1, stride, 0, q,
                ));
            }
            n.push_unchecked(add(c_out, h_out, h_out, block_in, q));
            c_in = c_out;
        }
    }
    n.push(gap(2048, 7, 7, q));
    n.push(Layer::fc("fc", 2048, 1000, q));
    n
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resnet18_exact_params() {
        // torchvision resnet18 conv+fc params (no BN): 11_679_912... we count
        // conv + fc weights without biases/BN: 11,671,488 + fc 512,000 =
        // known value ~11.68M.
        let n = resnet18(Quant::W8A8);
        let p = n.stats().params;
        assert!((11_400_000..12_000_000).contains(&p), "{p}");
    }

    #[test]
    fn resnet34_params_and_layers() {
        let n = resnet34(Quant::W8A8);
        let p = n.stats().params;
        // torchvision resnet34 conv+fc (no BN/bias): ~21.8M
        assert!((21_000_000..22_300_000).contains(&p), "{p}");
        // 1 stem + 32 block convs + 3 downsample + 1 fc = 37
        assert_eq!(n.stats().weight_layers, 37);
    }

    #[test]
    fn resnet50_exact_params() {
        let n = resnet50(Quant::W8A8);
        let p = n.stats().params;
        assert!((25_000_000..26_200_000).contains(&p), "{p}");
    }

    #[test]
    fn resnet18_macs_about_1_8g() {
        let m = resnet18(Quant::W8A8).stats().macs;
        assert!((1_700_000_000..1_950_000_000).contains(&m), "{m}");
    }

    #[test]
    fn resnet50_weight_layer_count() {
        // 1 stem + 48 block convs + 4 downsample + 1 fc = 54
        assert_eq!(resnet50(Quant::W8A8).stats().weight_layers, 54);
    }

    #[test]
    fn eltwise_adds_reference_earlier_layers() {
        let n = resnet18(Quant::W8A8);
        for (i, l) in n.layers.iter().enumerate() {
            if let Some(s) = l.skip_from {
                assert!(s < i, "skip_from must point backwards");
            }
        }
    }
}
