//! VGG-16 (Simonyan & Zisserman, 2015) — not in the paper's tables, but a
//! classic stress case for weights streaming: 89% of its 138M parameters sit
//! in the first FC layer, making it the extreme the fragmentation scheme was
//! designed for.

use crate::ir::{Layer, Network, OpKind, PoolKind, Quant};

fn pool(c: u32, hw: u32, q: Quant) -> Layer {
    Layer {
        name: format!("pool{hw}"),
        op: OpKind::Pool { kernel: 2, stride: 2, pad: 0, kind: PoolKind::Max },
        c_in: c,
        c_out: c,
        h_in: hw,
        w_in: hw,
        quant: q,
        skip_from: None,
    }
}

/// VGG-16: 13 conv layers + 3 FC layers. ~138M parameters.
pub fn vgg16(q: Quant) -> Network {
    let mut n = Network::new("vgg16", (3, 224, 224), q);
    let cfg: [(u32, u32, u32); 5] = [
        // (channels, convs in group, input spatial)
        (64, 2, 224),
        (128, 2, 112),
        (256, 3, 56),
        (512, 3, 28),
        (512, 3, 14),
    ];
    let mut c_in = 3u32;
    for (gi, &(c, convs, hw)) in cfg.iter().enumerate() {
        for ci in 0..convs {
            n.push(Layer::conv(
                format!("conv{}_{}", gi + 1, ci + 1),
                c_in, c, hw, hw, 3, 1, 1, q,
            ));
            c_in = c;
        }
        n.push(pool(c, hw, q));
    }
    // flatten 512*7*7 -> fc chain
    n.push_unchecked(Layer::fc("fc6", 512 * 7 * 7, 4096, q));
    n.push(Layer::fc("fc7", 4096, 4096, q));
    n.push(Layer::fc("fc8", 4096, 1000, q));
    n
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn params_are_138m() {
        let p = vgg16(Quant::W8A8).stats().params;
        assert!((136_000_000..140_000_000).contains(&p), "{p}");
    }

    #[test]
    fn fc6_dominates() {
        let n = vgg16(Quant::W8A8);
        let fc6 = n.layers.iter().find(|l| l.name == "fc6").unwrap();
        assert_eq!(fc6.weight_count(), 512 * 49 * 4096);
        assert!(fc6.weight_count() * 10 > n.stats().params * 7);
    }

    #[test]
    fn sixteen_weight_layers() {
        assert_eq!(vgg16(Quant::W8A8).stats().weight_layers, 16);
    }
}
