//! AlexNet (Krizhevsky et al., 2012) for 224x224 ImageNet input.
//!
//! The FC-dominated extreme of the zoo: 58M of its 61M parameters live in
//! three fully-connected layers. For the AutoWS DSE this is the
//! best-case workload for weight streaming — FC weights are used exactly
//! once per sample (no spatial reuse, `ĥ = ŵ = 1`), so evicting them costs
//! the minimum possible bandwidth per byte saved. The paper's Eq. 5
//! predicts FC layers are the first to stream; this model makes that
//! behaviour dominant and easy to test.

use crate::ir::{Layer, Network, OpKind, PoolKind, Quant};

fn maxpool(name: &str, c: u32, h: u32, w: u32, q: Quant) -> Layer {
    Layer {
        name: name.into(),
        op: OpKind::Pool { kernel: 3, stride: 2, pad: 0, kind: PoolKind::Max },
        c_in: c,
        c_out: c,
        h_in: h,
        w_in: w,
        quant: q,
        skip_from: None,
    }
}

/// AlexNet: 5 convs + 3 pools + 3 FC.
pub fn alexnet(q: Quant) -> Network {
    let mut n = Network::new("alexnet", (3, 224, 224), q);
    n.push(Layer::conv("conv1", 3, 64, 224, 224, 11, 4, 2, q)); // 55x55
    n.push(maxpool("pool1", 64, 55, 55, q)); // 27x27
    n.push(Layer::conv("conv2", 64, 192, 27, 27, 5, 1, 2, q));
    n.push(maxpool("pool2", 192, 27, 27, q)); // 13x13
    n.push(Layer::conv("conv3", 192, 384, 13, 13, 3, 1, 1, q));
    n.push(Layer::conv("conv4", 384, 256, 13, 13, 3, 1, 1, q));
    n.push(Layer::conv("conv5", 256, 256, 13, 13, 3, 1, 1, q));
    n.push(maxpool("pool5", 256, 13, 13, q)); // 6x6
    // flatten 256*6*6 -> fc chain
    n.push_unchecked(Layer::fc("fc6", 256 * 6 * 6, 4096, q));
    n.push(Layer::fc("fc7", 4096, 4096, q));
    n.push(Layer::fc("fc8", 4096, 1000, q));
    n
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn params_about_61m() {
        let p = alexnet(Quant::W8A8).stats().params;
        // torchvision alexnet (weights only, no biases): ~61.1M - 10.6k bias
        assert!((60_000_000..61_500_000).contains(&p), "{p}");
    }

    #[test]
    fn fc_dominates_params() {
        let n = alexnet(Quant::W8A8);
        let fc: u64 = n
            .layers
            .iter()
            .filter(|l| l.name.starts_with("fc"))
            .map(|l| l.weight_count())
            .sum();
        assert!(fc * 10 > n.stats().params * 9, "FC holds >90% of params");
    }

    #[test]
    fn dse_streams_fc_first() {
        // On a memory-tight device the greedy ΔB rule must evict the FC
        // layers before any conv: FC has zero spatial reuse, so Eq. 5 gives
        // it the lowest bandwidth cost per evicted block.
        use crate::device::Device;
        use crate::dse::{self, DseConfig};
        let n = alexnet(Quant::W4A4);
        let dev = Device::zcu102();
        let r = dse::run(&n, &dev, &DseConfig::default()).expect("feasible with streaming");
        let design = &r.design;
        let streamed: Vec<&str> = design
            .streaming_layers()
            .into_iter()
            .map(|i| design.network.layers[i].name.as_str())
            .collect();
        assert!(
            streamed.iter().any(|s| s.starts_with("fc")),
            "some FC layer must stream: {streamed:?}"
        );
        // fc6 (the 37M-param giant) must be the most-evicted layer
        let fc6 = design
            .network
            .layers
            .iter()
            .position(|l| l.name == "fc6")
            .unwrap();
        assert!(
            design.cfgs[fc6].frag.off_chip_ratio() > 0.5,
            "fc6 should be mostly off-chip, got {:.0}%",
            design.cfgs[fc6].frag.off_chip_ratio() * 100.0
        );
    }

    #[test]
    fn macs_about_0_7g() {
        let m = alexnet(Quant::W8A8).stats().macs;
        assert!((650_000_000..780_000_000).contains(&m), "{m}");
    }
}
