//! Model zoo: builders for the networks in the paper's evaluation
//! (Table I: MobileNetV2, ResNet18, ResNet50; §V-D: YOLOv5n) plus VGG16 and
//! the toy CNN used by the end-to-end PJRT serving example.
//!
//! All builders produce the layer chain in pipeline order. Residual
//! downsample convolutions and merge points are appended with
//! `push_unchecked` because their dataflow input is a skip FIFO, not the
//! previous chain element.

mod alexnet;
mod mobilenetv2;
mod resnet;
mod squeezenet;
mod toy;
mod vgg;
mod yolov5;

pub use alexnet::alexnet;
pub use mobilenetv2::mobilenet_v2;
pub use resnet::{resnet18, resnet34, resnet50};
pub use squeezenet::squeezenet;
pub use toy::toy_cnn;
pub use vgg::vgg16;
pub use yolov5::yolov5n;

use crate::ir::{Network, Quant};

/// Look up a model by name with the default 224x224 ImageNet input
/// (640x640 for YOLOv5n, 32x32 for the toy CNN).
pub fn by_name(name: &str, quant: Quant) -> Option<Network> {
    match name.to_ascii_lowercase().as_str() {
        "mobilenetv2" | "mobilenet_v2" => Some(mobilenet_v2(quant)),
        "resnet18" => Some(resnet18(quant)),
        "resnet34" => Some(resnet34(quant)),
        "resnet50" => Some(resnet50(quant)),
        "squeezenet" => Some(squeezenet(quant)),
        "alexnet" => Some(alexnet(quant)),
        "yolov5n" => Some(yolov5n(quant)),
        "vgg16" => Some(vgg16(quant)),
        "toy" | "toy_cnn" => Some(toy_cnn(quant)),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Paper Table I: params within 5% of the published counts.
    #[test]
    fn table1_param_counts() {
        let cases = [
            ("mobilenetv2", 3.5e6),
            ("resnet18", 11.7e6),
            ("resnet50", 25.6e6),
        ];
        for (name, expect) in cases {
            let n = by_name(name, Quant::W8A8).unwrap();
            let p = n.stats().params as f64;
            let err = (p - expect).abs() / expect;
            assert!(err < 0.05, "{name}: {p} params vs paper {expect} ({:.1}% off)", err * 100.0);
        }
    }

    /// Paper Table I: MACs within 15% of the published counts
    /// (0.3G / 1.8G / 4.1G).
    #[test]
    fn table1_mac_counts() {
        let cases = [
            ("mobilenetv2", 0.3e9),
            ("resnet18", 1.8e9),
            ("resnet50", 4.1e9),
        ];
        for (name, expect) in cases {
            let n = by_name(name, Quant::W8A8).unwrap();
            let m = n.stats().macs as f64;
            let err = (m - expect).abs() / expect;
            assert!(err < 0.15, "{name}: {m} MACs vs paper {expect} ({:.1}% off)", err * 100.0);
        }
    }

    /// Paper Fig. 7 shows 21 weight layers for ResNet18.
    #[test]
    fn resnet18_has_21_weight_layers() {
        let n = resnet18(Quant::W4A5);
        assert_eq!(n.stats().weight_layers, 21);
    }

    #[test]
    fn yolov5n_param_count() {
        let n = yolov5n(Quant::W8A8);
        let p = n.stats().params as f64;
        assert!((1.5e6..2.3e6).contains(&p), "yolov5n params {p} (expected ~1.9M)");
    }

    #[test]
    fn all_models_have_consistent_stats() {
        for name in [
            "mobilenetv2",
            "resnet18",
            "resnet34",
            "resnet50",
            "squeezenet",
            "alexnet",
            "yolov5n",
            "vgg16",
            "toy",
        ] {
            let n = by_name(name, Quant::W8A8).unwrap();
            let s = n.stats();
            assert!(s.params > 0, "{name}");
            assert!(s.macs >= s.params, "{name}: macs {} < params {}", s.macs, s.params);
            assert_eq!(s.weight_bits, s.params * 8, "{name}");
            assert!(s.weight_layers <= s.total_layers, "{name}");
        }
    }

    #[test]
    fn unknown_model_is_none() {
        assert!(by_name("alexnet9000", Quant::W8A8).is_none());
    }
}
