//! Greedy Design Space Exploration — paper §IV-A, Algorithm 1.
//!
//! The optimization problem (Eq. 6):
//!
//! ```text
//! max  min_l θ_l   s.t.   β_io + Σ_l s_l·β_l ≤ B,   Σ_l a_l ≤ A
//! ```
//!
//! solved greedily in two interleaved phases:
//! - **compute allocation** ([`compute_alloc`]): repeatedly unroll the
//!   slowest CE by step `φ`;
//! - **memory allocation** ([`memory_alloc`]): whenever on-chip memory
//!   exceeds the budget, evict depth-`μ` blocks to off-chip from the layer
//!   with minimal bandwidth impact ΔB, re-balancing write bursts (Eq. 10).
//!
//! §Perf: the evaluation engine is incremental — O(1) aggregate queries and
//! undo-log trials on [`Design`], a lazily invalidated min-ΔB heap in the
//! eviction loop, and optionally ([`DseConfig::warm_start`]) memory re-fits
//! that keep the previous eviction state. [`reference`] preserves the
//! pre-refactor recompute-from-scratch engine as the equivalence oracle and
//! the "before" side of `benches/dse_perf.rs`.

mod ablation;
pub mod colocate;
mod compute_alloc;
mod design;
mod exhaustive;
pub mod fleet;
mod memory_alloc;
pub mod partition;
pub mod reference;
mod search;
mod serialize;
mod sweep;

pub use ablation::{balanced_and_unbalanced, phi_mu_sweep, unbalanced_variant, HyperPoint};
pub use colocate::{ColocatedResult, TenantPlan};
pub use compute_alloc::{allocate_compute, increment_unroll};
pub use design::Design;
pub use exhaustive::{exhaustive_memory, ExhaustiveResult};
pub use fleet::{slo_metric, FleetObjective, FleetPlacement, FleetResult};
pub use memory_alloc::{
    allocate_memory, allocate_memory_warm, delta_bandwidth, delta_bandwidth_by,
    increment_offchip, increment_offchip_by, r_target, rebalance_all, write_burst_balance,
};
pub use partition::{PartitionPlan, PartitionedResult};
pub use search::{anneal, random_search, run_with_strategy, Strategy};
pub use serialize::{parse_design, serialize_design, DesignFormatError};
pub use sweep::{mem_sweep, parallel_cases, SweepPoint};

use crate::device::Device;
use crate::ir::Network;

/// DSE hyperparameters (paper: `φ` unroll step, `μ` eviction block depth)
/// plus the run mode.
#[derive(Debug, Clone, Copy)]
pub struct DseConfig {
    /// Unroll step size `φ` (Algorithm 1 INCREMENT_UNROLL).
    pub phi: u32,
    /// Eviction block depth `μ` in words (Algorithm 1 INCREMENT_OFFCHIP).
    pub mu: u64,
    /// Batch size `b` used for weight-reuse accounting (Eq. 3).
    pub batch: u64,
    /// When false, ALLOCATE_MEMORY is forbidden from evicting — this is the
    /// "vanilla layer-pipelined" baseline (fpgaConvNet): the design is
    /// infeasible if the weights do not fit on-chip.
    pub allow_streaming: bool,
    /// Fraction of the device bandwidth `B` the DSE may plan against.
    /// Saturating B to 100% leaves the burst schedule no phase slack, so
    /// transient Read-After-Write stalls appear; a small margin keeps the
    /// deterministic schedule stall-free (validated by the simulator).
    pub bw_margin: f64,
    /// When true, the memory re-fit after each unroll warm-starts from the
    /// previous eviction state ([`allocate_memory_warm`]) instead of
    /// resetting every layer to on-chip and re-deriving the whole eviction
    /// set. Identical results on workloads that never stream; on
    /// eviction-heavy workloads the repaired eviction set is a greedy
    /// approximation of the re-derived one (same budget/bandwidth
    /// guarantees, chunk rounding may differ), which is why this is opt-in.
    pub warm_start: bool,
}

impl Default for DseConfig {
    fn default() -> Self {
        DseConfig {
            phi: 1,
            mu: 512,
            batch: 1,
            allow_streaming: true,
            bw_margin: 0.90,
            warm_start: false,
        }
    }
}

impl DseConfig {
    pub fn vanilla() -> Self {
        DseConfig::default().with_streaming(false)
    }

    /// Default configuration with warm-started memory re-fits.
    pub fn warm() -> Self {
        DseConfig::default().with_warm_start(true)
    }

    // Builder-style setters (the config is `Copy`, so these chain freely):
    // `DseConfig::default().with_phi(2).with_mu(256)`.

    /// Set the unroll step size `φ`.
    pub fn with_phi(mut self, phi: u32) -> Self {
        self.phi = phi;
        self
    }

    /// Set the eviction block depth `µ` (words).
    pub fn with_mu(mut self, mu: u64) -> Self {
        self.mu = mu;
        self
    }

    /// Set the batch size `b` used for weight-reuse accounting (Eq. 3).
    pub fn with_batch(mut self, batch: u64) -> Self {
        self.batch = batch;
        self
    }

    /// Allow (AutoWS) or forbid (vanilla baseline) weight streaming.
    pub fn with_streaming(mut self, allow: bool) -> Self {
        self.allow_streaming = allow;
        self
    }

    /// Set the planning fraction of the device bandwidth.
    pub fn with_bw_margin(mut self, margin: f64) -> Self {
        self.bw_margin = margin;
        self
    }

    /// Enable/disable warm-started memory re-fits.
    pub fn with_warm_start(mut self, warm: bool) -> Self {
        self.warm_start = warm;
        self
    }
}

/// Outcome of a DSE run.
#[derive(Debug, Clone)]
pub struct DseResult {
    pub design: Design,
    /// Pipeline throughput `min_l θ_l` in samples/s.
    pub throughput: f64,
    /// Analytic single-sample latency in milliseconds.
    pub latency_ms: f64,
    /// Total area.
    pub area: crate::ce::Area,
    /// Total off-chip bandwidth demand `β_io + Σ s_l β_l` (bits/s).
    pub bandwidth_bps: f64,
    /// Number of greedy iterations executed (compute increments).
    pub iterations: usize,
}

/// Run Algorithm 1 end-to-end for `network` on `device`.
///
/// Returns `None` when no feasible design exists: for the vanilla baseline
/// this is the "X" of paper Table II (weights do not fit on-chip); with
/// streaming enabled it only happens if even the fully-evicted serial design
/// exceeds the device (pathological).
pub fn run(network: &Network, device: &Device, cfg: &DseConfig) -> Option<DseResult> {
    // INITIALIZE(D): unroll factors 1, all weights on-chip.
    let mut design = Design::initialize(network, device);

    // Make the initial design memory-feasible before any compute allocation.
    // (Nothing streams yet, so the warm and cold paths coincide here.)
    if !allocate_memory(&mut design, device, cfg) {
        return None;
    }
    if !design.total_area().fits(device) {
        return None;
    }

    // ALLOCATE_COMPUTE (which re-runs ALLOCATE_MEMORY after every unroll).
    let iterations = allocate_compute(&mut design, device, cfg);
    crate::telemetry::counters().dse_greedy_steps.add(iterations as u64);

    let throughput = design.min_throughput();
    Some(DseResult {
        throughput,
        latency_ms: design.latency_ms(1),
        area: design.total_area(),
        bandwidth_bps: design.total_bandwidth(),
        iterations,
        design,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::Quant;
    use crate::models;

    #[test]
    fn toy_on_large_device_is_compute_bound_all_onchip() {
        let net = models::toy_cnn(Quant::W8A8);
        let dev = Device::u250();
        let r = run(&net, &dev, &DseConfig::default()).unwrap();
        // plenty of memory: the greedy DSE keeps everything on-chip
        assert!(!r.design.any_streaming(), "no eviction needed on U250");
        assert!(r.throughput > 1000.0, "θ = {}", r.throughput);
    }

    #[test]
    fn vanilla_equals_autows_on_large_device() {
        let net = models::resnet18(Quant::W4A5);
        let dev = Device::u250();
        let a = run(&net, &dev, &DseConfig::default()).unwrap();
        let v = run(&net, &dev, &DseConfig::vanilla()).unwrap();
        let ratio = a.throughput / v.throughput;
        assert!((0.8..1.25).contains(&ratio), "AutoWS {} vs vanilla {}", a.throughput, v.throughput);
    }

    #[test]
    fn vanilla_infeasible_where_autows_feasible() {
        // ResNet18 W4A5 weights ~5.9 MB vs Zedboard 1.2 MB on-chip.
        let net = models::resnet18(Quant::W4A5);
        let dev = Device::zedboard();
        assert!(run(&net, &dev, &DseConfig::vanilla()).is_none(), "vanilla must not fit");
        let a = run(&net, &dev, &DseConfig::default()).expect("AutoWS must fit");
        assert!(a.design.any_streaming());
        assert!(a.throughput > 0.0);
    }

    #[test]
    fn feasible_design_respects_constraints() {
        let net = models::resnet18(Quant::W4A5);
        let dev = Device::zcu102();
        let r = run(&net, &dev, &DseConfig::default()).unwrap();
        assert!(r.area.fits(&dev), "area {:?}", r.area);
        assert!(
            r.bandwidth_bps <= dev.bandwidth_bps * 1.0001,
            "bw {} > {}",
            r.bandwidth_bps,
            dev.bandwidth_bps
        );
    }

    #[test]
    fn more_memory_never_hurts() {
        let net = models::resnet18(Quant::W4A5);
        let small = Device::zcu102().with_mem_scale(0.6);
        let large = Device::zcu102();
        let ts = run(&net, &small, &DseConfig::default()).unwrap().throughput;
        let tl = run(&net, &large, &DseConfig::default()).unwrap().throughput;
        assert!(tl >= ts * 0.95, "θ(small)={ts} θ(large)={tl}");
    }

    #[test]
    fn warm_start_stays_feasible_on_streaming_workload() {
        let net = models::resnet18(Quant::W4A5);
        let dev = Device::zcu102();
        let r = run(&net, &dev, &DseConfig::warm()).expect("warm-start run must be feasible");
        assert!(r.area.fits(&dev));
        assert!(r.bandwidth_bps <= dev.bandwidth_bps * 1.0001);
        assert!(r.throughput > 0.0);
        r.design.assert_aggregates_consistent();
    }
}
