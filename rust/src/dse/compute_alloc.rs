//! Greedy compute allocation — Algorithm 1 procedures INCREMENT_UNROLL and
//! ALLOCATE_COMPUTE.

use super::{allocate_memory, allocate_memory_warm, Design, DseConfig};
use crate::ce::next_unroll;
use crate::device::Device;
use crate::ir::OpKind;

/// INCREMENT_UNROLL: advance the first unsaturated unroll dimension of layer
/// `l` — priority order `k², f, c` as in Algorithm 1 — by at least `φ`
/// (rounded up to the next divisor). Returns `false` when the layer is fully
/// unrolled (its CE cannot be made faster).
pub fn increment_unroll(design: &mut Design, l: usize, phi: u32) -> bool {
    // (dimension size, current value) in Algorithm 1's priority order.
    let mut dims = [(0u32, 0u32, 0u8); 3];
    let ndims = {
        let layer = &design.network.layers[l];
        let cfg = &design.cfgs[l];
        let k2 = layer.kernel() * layer.kernel();
        match layer.op {
            OpKind::Conv { .. } => {
                dims = [
                    (k2, cfg.kp, 0),
                    (layer.c_out, cfg.fp, 1),
                    (layer.c_per_group(), cfg.cp, 2),
                ];
                3
            }
            OpKind::Fc => {
                dims[0] = (layer.c_out, cfg.fp, 1);
                dims[1] = (layer.c_in, cfg.cp, 2);
                2
            }
            OpKind::Pool { .. } => {
                dims[0] = (k2, cfg.kp, 0);
                dims[1] = (layer.c_in, cfg.cp, 2);
                2
            }
            _ => {
                dims[0] = (layer.c_in, cfg.cp, 2);
                1
            }
        }
    };

    for &(size, current, which) in &dims[..ndims] {
        if current < size {
            if let Some(next) = next_unroll(size, current, phi) {
                design.record_layer(l);
                match which {
                    0 => design.cfgs[l].kp = next,
                    1 => design.cfgs[l].fp = next,
                    _ => design.cfgs[l].cp = next,
                }
                // geometry changed: re-derive the fragmentation from the
                // invariant evicted-bits, keeping the current burst count.
                let n = design.cfgs[l].frag.n;
                design.set_fragmentation(l, n);
                return true;
            }
        }
    }
    false
}

/// ALLOCATE_COMPUTE: repeatedly unroll the slowest CE, re-running memory
/// allocation after each step; stop when the area budget, the bandwidth
/// budget, or full unrolling of the bottleneck is reached. Returns the
/// number of accepted increments.
///
/// §Perf: each proposal used to deep-clone the whole `Design`; it now runs
/// as an undo-log trial ([`Design::begin_trial`]) that snapshots only the
/// layers the proposal touches and rolls back bit-exactly on rejection.
/// With [`DseConfig::warm_start`] the memory re-fit also keeps the previous
/// eviction state instead of re-deriving it from scratch.
pub fn allocate_compute(design: &mut Design, device: &Device, cfg: &DseConfig) -> usize {
    let mut accepted = 0;
    loop {
        let l = design.slowest();
        design.begin_trial();
        if !increment_unroll(design, l, cfg.phi) {
            design.rollback_trial();
            break; // bottleneck CE saturated: θ cannot improve further
        }
        let fitted = if cfg.warm_start {
            allocate_memory_warm(design, device, cfg)
        } else {
            allocate_memory(design, device, cfg)
        };
        if !fitted
            || !design.total_area().fits(device)
            || design.total_bandwidth() > device.bandwidth_bps * cfg.bw_margin
        {
            design.rollback_trial();
            break; // area or bandwidth limit reached
        }
        design.commit_trial();
        accepted += 1;
    }
    accepted
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::Quant;
    use crate::models;

    fn setup() -> (Design, Device) {
        let net = models::toy_cnn(Quant::W8A8);
        let dev = Device::zcu102();
        (Design::initialize(&net, &dev), dev)
    }

    #[test]
    fn increment_follows_priority_order() {
        let (mut d, _) = setup();
        // conv layer: k² first
        assert!(increment_unroll(&mut d, 0, 1));
        assert!(d.cfgs[0].kp > 1);
        assert_eq!(d.cfgs[0].fp, 1);
        assert_eq!(d.cfgs[0].cp, 1);
    }

    #[test]
    fn increment_saturates_k_then_moves_to_f() {
        let (mut d, _) = setup();
        // saturate k² (divisors of 9: 1,3,9 -> two increments)
        assert!(increment_unroll(&mut d, 0, 1));
        assert!(increment_unroll(&mut d, 0, 1));
        assert_eq!(d.cfgs[0].kp, 9);
        assert!(increment_unroll(&mut d, 0, 1));
        assert!(d.cfgs[0].fp > 1, "after k² saturates, f is next");
    }

    #[test]
    fn increment_eventually_saturates() {
        let (mut d, _) = setup();
        let mut steps = 0;
        while increment_unroll(&mut d, 4, 8) {
            steps += 1;
            assert!(steps < 1000, "must terminate");
        }
        // fc layer fully unrolled
        assert_eq!(d.cfgs[4].fp, 10);
        assert_eq!(d.cfgs[4].cp, 64);
    }

    #[test]
    fn allocate_compute_improves_throughput() {
        let (mut d, dev) = setup();
        let before = d.min_throughput();
        let iters = allocate_compute(&mut d, &dev, &DseConfig::default());
        assert!(iters > 0);
        assert!(d.min_throughput() > before * 10.0, "toy net on zcu102 should unroll a lot");
        assert!(d.total_area().fits(&dev));
        assert!(!d.trial_open(), "trial must be closed after the loop");
        d.assert_aggregates_consistent();
    }

    #[test]
    fn allocate_compute_respects_small_device() {
        let net = models::resnet18(Quant::W4A5);
        let dev = Device::zedboard();
        let cfg = DseConfig::default();
        let mut d = Design::initialize(&net, &dev);
        assert!(allocate_memory(&mut d, &dev, &cfg));
        allocate_compute(&mut d, &dev, &cfg);
        assert!(d.total_area().fits(&dev));
        assert!(d.total_bandwidth() <= dev.bandwidth_bps * 1.0001);
        d.assert_aggregates_consistent();
    }

    #[test]
    fn warm_start_matches_cold_when_nothing_streams() {
        // Toy CNN on U250 never needs eviction, so the warm memory path is
        // step-for-step identical to the cold one.
        let net = models::toy_cnn(Quant::W8A8);
        let dev = Device::u250();
        let cfg_cold = DseConfig::default();
        let cfg_warm = DseConfig::warm();
        let mut cold = Design::initialize(&net, &dev);
        let mut warm = Design::initialize(&net, &dev);
        assert!(allocate_memory(&mut cold, &dev, &cfg_cold));
        assert!(allocate_memory_warm(&mut warm, &dev, &cfg_warm));
        let ic = allocate_compute(&mut cold, &dev, &cfg_cold);
        let iw = allocate_compute(&mut warm, &dev, &cfg_warm);
        assert_eq!(ic, iw);
        assert_eq!(cold.cfgs, warm.cfgs);
        assert_eq!(cold.off_bits, warm.off_bits);
        assert!(!cold.any_streaming());
    }
}
