//! Fleet placement search — N networks over M heterogeneous devices.
//!
//! [`super::partition`] answers "one model, many devices" and
//! [`super::colocate`] answers "many models, one device"; this module is the
//! general case on top of both: a bin-packing/assignment search that places
//! every network of a model set onto a heterogeneous device pool, choosing
//! per model whether to run **solo** on one board, **shard** across a device
//! subset (the PR-4 cut search), or **co-locate** with other tenants on a
//! shared board (the PR-5 joint budget search).
//!
//! The search is a deterministic greedy:
//!
//! 1. Evaluate the full solo matrix (model × device) up front, fanned across
//!    cores via [`super::parallel_cases`].
//! 2. Place models in descending weight-footprint order (the biggest model
//!    has the fewest placement options, so it chooses first; ties keep input
//!    order — the sort is stable).
//! 3. For each model, enumerate candidates per the objective (below), every
//!    candidate evaluation going through the caller's
//!    [`DesignCache`](crate::pipeline::DesignCache) — fleets re-probe the
//!    same (network, device-subset) points constantly, and the cache shares
//!    those entries with the plain single/partitioned/colocated pipelines.
//! 4. Under [`FleetObjective::MaxAggregateThroughput`], finish with an
//!    improvement pass that widens the slowest solo/sharded placement onto
//!    leftover free devices while that helps.
//!
//! Objective semantics:
//!
//! - **MaxAggregateThroughput** — maximize Σθ over all models. Per model:
//!   best feasible solo on a free device; else the smallest feasible shard
//!   over free devices with the best θ; else co-locate onto the existing
//!   group with the best *marginal* aggregate θ.
//! - **MinDevicesAtSlo { p99_ms }** — use as few boards as possible while
//!   every model's tail-latency proxy ([`slo_metric`]) stays within the SLO.
//!   Candidates are tiered by how many *new* devices they claim: co-locating
//!   onto an occupied board costs 0, solo costs 1, a k-way shard costs k; the
//!   cheapest tier with any SLO-meeting candidate wins (θ breaks ties). A
//!   co-location candidate only qualifies if **every** tenant of the grown
//!   group still meets the SLO. If some model meets the SLO nowhere, the
//!   whole fleet is infeasible (`None`) — same contract as a plain DSE miss.
//!
//! Degenerate shapes reproduce the established searches *verbatim* so the
//! fleet surface is a strict superset: 1 model × 1 device is the plain DSE,
//! 1 model × M devices (under MaxAggregateThroughput) is the PR-4 partition
//! of the full chain, N models × 1 device is the PR-5 co-location. The
//! `tests/fleet_deploy.rs` goldens pin these bit-identically.

use crate::device::Device;
use crate::ir::Network;
use crate::pipeline::DesignCache;

use super::{parallel_cases, ColocatedResult, DseConfig, DseResult, PartitionedResult};

/// What the fleet search optimizes for.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FleetObjective {
    /// Maximize the sum of all models' steady-state throughputs, using the
    /// whole pool if it helps.
    MaxAggregateThroughput,
    /// Occupy as few devices as possible while every model's tail-latency
    /// proxy ([`slo_metric`]) stays at or below `p99_ms`.
    MinDevicesAtSlo { p99_ms: f64 },
}

/// One placement decision of a [`FleetResult`]. Model and device fields are
/// **indices into the input lists** handed to [`fleet`], so a placement can
/// be joined back to its network/board without cloning either.
#[derive(Debug, Clone)]
pub enum FleetPlacement {
    /// One model alone on one board (plain DSE outcome).
    Solo { model: usize, device: usize, result: DseResult },
    /// One model split across a device chain (`devices` in chain order).
    Sharded { model: usize, devices: Vec<usize>, result: PartitionedResult },
    /// Several models sharing one board (`models` in tenant order — the
    /// order the joint search saw them, which is their placement order).
    Colocated { models: Vec<usize>, device: usize, result: ColocatedResult },
}

impl FleetPlacement {
    /// The models this placement serves, in tenant order.
    pub fn model_indices(&self) -> Vec<usize> {
        match self {
            FleetPlacement::Solo { model, .. } => vec![*model],
            FleetPlacement::Sharded { model, .. } => vec![*model],
            FleetPlacement::Colocated { models, .. } => models.clone(),
        }
    }

    /// The devices this placement occupies, in chain order.
    pub fn device_indices(&self) -> Vec<usize> {
        match self {
            FleetPlacement::Solo { device, .. } => vec![*device],
            FleetPlacement::Sharded { devices, .. } => devices.clone(),
            FleetPlacement::Colocated { device, .. } => vec![*device],
        }
    }

    /// Steady-state throughput this placement contributes to the aggregate:
    /// the model's θ for solo/sharded, the tenant sum for co-located.
    pub fn throughput(&self) -> f64 {
        match self {
            FleetPlacement::Solo { result, .. } => result.throughput,
            FleetPlacement::Sharded { result, .. } => result.throughput,
            FleetPlacement::Colocated { result, .. } => result.aggregate_throughput(),
        }
    }

    /// Placement-mode label for tables and JSON (`solo`/`sharded`/`colocated`).
    pub fn mode(&self) -> &'static str {
        match self {
            FleetPlacement::Solo { .. } => "solo",
            FleetPlacement::Sharded { .. } => "sharded",
            FleetPlacement::Colocated { .. } => "colocated",
        }
    }
}

/// Outcome of a fleet placement search.
#[derive(Debug, Clone)]
pub struct FleetResult {
    /// Placement decisions in the order the greedy committed them (largest
    /// model first; a co-location replaces the solo placement it grew from).
    pub placements: Vec<FleetPlacement>,
    /// The objective this result was searched under.
    pub objective: FleetObjective,
    /// Number of distinct devices the placements occupy.
    pub devices_used: usize,
    /// Σθ over all placements (samples/s).
    pub aggregate_throughput: f64,
}

impl FleetResult {
    /// The placement serving model `m` (an index into the input network
    /// list), if any.
    pub fn placement_of(&self, m: usize) -> Option<&FleetPlacement> {
        self.placements.iter().find(|p| p.model_indices().contains(&m))
    }
}

/// Tail-latency proxy of a steady-state deployment point: the analytic
/// single-sample latency plus one service period (`1/θ`, in ms). At
/// saturation an arriving request waits out the in-flight sample before its
/// own pipeline traversal, so this is the p99 *floor* the deployment can
/// promise — sharding a memory-starved model shrinks both terms, which is
/// exactly the lever [`FleetObjective::MinDevicesAtSlo`] needs.
pub fn slo_metric(latency_ms: f64, throughput: f64) -> f64 {
    if throughput <= 0.0 {
        return f64::INFINITY;
    }
    latency_ms + 1e3 / throughput
}

/// Place `networks` onto `devices` under `objective`, memoizing every
/// candidate evaluation in the process-wide
/// [`design_cache`](crate::pipeline::design_cache). Returns `None` when no
/// feasible placement of the whole set exists (or, under
/// [`FleetObjective::MinDevicesAtSlo`], when some model meets the SLO
/// nowhere).
pub fn fleet(
    networks: &[Network],
    devices: &[Device],
    objective: FleetObjective,
    cfg: &DseConfig,
) -> Option<FleetResult> {
    fleet_in(crate::pipeline::design_cache(), networks, devices, objective, cfg)
}

/// [`fleet`] against a caller-owned cache — the entry point
/// [`DesignCache::explore_fleet`](crate::pipeline::DesignCache::explore_fleet)
/// uses so sub-evaluations land in the *same* cache instance that memoizes
/// the whole fleet outcome.
pub fn fleet_in(
    cache: &DesignCache,
    networks: &[Network],
    devices: &[Device],
    objective: FleetObjective,
    cfg: &DseConfig,
) -> Option<FleetResult> {
    let n = networks.len();
    let m = devices.len();
    if n == 0 || m == 0 {
        return None;
    }

    // Degenerate shapes reproduce the established searches verbatim (the
    // pipeline goldens pin these bit-identically against `.on_device`,
    // `.on_devices` and `.colocate`).
    if n == 1 && m == 1 {
        let (result, _) = cache.explore(&networks[0], &devices[0], cfg);
        let result = result?;
        if let FleetObjective::MinDevicesAtSlo { p99_ms } = objective {
            if slo_metric(result.latency_ms, result.throughput) > p99_ms {
                return None;
            }
        }
        return Some(finish(vec![FleetPlacement::Solo { model: 0, device: 0, result }], objective));
    }
    if n == 1 && objective == FleetObjective::MaxAggregateThroughput {
        // One model over a pool IS the PR-4 sharded deployment of the full
        // chain. (Under MinDevicesAtSlo the general greedy below applies —
        // it prefers one board if one board meets the SLO.)
        let (result, _) = cache.explore_partitioned(&networks[0], devices, None, cfg);
        let result = result?;
        return Some(finish(
            vec![FleetPlacement::Sharded { model: 0, devices: (0..m).collect(), result }],
            objective,
        ));
    }
    if m == 1 {
        // N models on one board IS the PR-5 co-location.
        let (result, _) = cache.explore_colocated(networks, &devices[0], cfg);
        let result = result?;
        if let FleetObjective::MinDevicesAtSlo { p99_ms } = objective {
            for t in &result.tenants {
                if slo_metric(t.result.latency_ms, t.result.throughput) > p99_ms {
                    return None;
                }
            }
        }
        return Some(finish(
            vec![FleetPlacement::Colocated { models: (0..n).collect(), device: 0, result }],
            objective,
        ));
    }

    // Solo matrix up front: cell (i, j) = model i alone on device j. Every
    // later candidate either reads a cell or goes through the cache, so the
    // fan-out cost is paid once.
    let pairs: Vec<(usize, usize)> =
        (0..n).flat_map(|i| (0..m).map(move |j| (i, j))).collect();
    let cells = parallel_cases(&pairs, |_, &(i, j)| cache.explore(&networks[i], &devices[j], cfg).0);
    let mut solo: Vec<Vec<Option<DseResult>>> = vec![vec![None; m]; n];
    for (&(i, j), r) in pairs.iter().zip(cells) {
        solo[i][j] = r;
    }

    // Biggest weight footprint places first; the stable sort keeps input
    // order on ties.
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by_key(|&i| std::cmp::Reverse(networks[i].stats().weight_bits));

    let mut placements: Vec<FleetPlacement> = Vec::new();
    for &mi in &order {
        place_one(cache, networks, devices, objective, cfg, &solo, mi, &mut placements)?;
    }

    if objective == FleetObjective::MaxAggregateThroughput {
        improve(cache, networks, devices, cfg, &mut placements);
    }

    Some(finish(placements, objective))
}

/// Devices no committed placement occupies, ascending.
fn free_devices(placements: &[FleetPlacement], m: usize) -> Vec<usize> {
    let mut taken = vec![false; m];
    for p in placements {
        for d in p.device_indices() {
            taken[d] = true;
        }
    }
    (0..m).filter(|&d| !taken[d]).collect()
}

/// Commit the placement of model `mi` under the objective, or fail the whole
/// fleet (`None`).
#[allow(clippy::too_many_arguments)]
fn place_one(
    cache: &DesignCache,
    networks: &[Network],
    devices: &[Device],
    objective: FleetObjective,
    cfg: &DseConfig,
    solo: &[Vec<Option<DseResult>>],
    mi: usize,
    placements: &mut Vec<FleetPlacement>,
) -> Option<()> {
    let free = free_devices(placements, devices.len());
    match objective {
        FleetObjective::MinDevicesAtSlo { p99_ms } => {
            // Tier 0: grow an existing solo/co-located group (0 new devices).
            if let Some((at, models, device, result)) =
                best_colocate(cache, networks, devices, cfg, mi, placements, |grown| {
                    grown
                        .tenants
                        .iter()
                        .all(|t| slo_metric(t.result.latency_ms, t.result.throughput) <= p99_ms)
                        .then(|| {
                            // tie-break θ: the new tenant's throughput
                            grown.tenants.last().map(|t| t.result.throughput).unwrap_or(0.0)
                        })
                })
            {
                placements[at] = FleetPlacement::Colocated { models, device, result };
                return Some(());
            }
            // Tier 1: solo on a free device.
            let best_solo = free
                .iter()
                .filter_map(|&d| {
                    let r = solo[mi][d].as_ref()?;
                    (slo_metric(r.latency_ms, r.throughput) <= p99_ms)
                        .then(|| (r.throughput, d, r.clone()))
                })
                .max_by(|a, b| {
                    a.0.partial_cmp(&b.0)
                        .unwrap_or(std::cmp::Ordering::Equal)
                        .then(b.1.cmp(&a.1)) // tie: lowest device index
                });
            if let Some((_, d, result)) = best_solo {
                placements.push(FleetPlacement::Solo { model: mi, device: d, result });
                return Some(());
            }
            // Tier k (k = 2..): the smallest shard over free devices that
            // meets the SLO; within the tier, best θ.
            for k in 2..=free.len() {
                let subsets = combinations(&free, k);
                let evals = parallel_cases(&subsets, |_, subset| {
                    let devs: Vec<Device> = subset.iter().map(|&d| devices[d].clone()).collect();
                    cache.explore_partitioned(&networks[mi], &devs, None, cfg).0
                });
                let best = subsets
                    .iter()
                    .zip(evals)
                    .filter_map(|(subset, r)| {
                        let r = r?;
                        (slo_metric(r.latency_ms(), r.throughput) <= p99_ms)
                            .then(|| (r.throughput, subset.clone(), r))
                    })
                    .max_by(|a, b| {
                        a.0.partial_cmp(&b.0)
                            .unwrap_or(std::cmp::Ordering::Equal)
                            .then(b.1.cmp(&a.1)) // tie: lexicographically lowest subset
                    });
                if let Some((_, subset, result)) = best {
                    placements.push(FleetPlacement::Sharded {
                        model: mi,
                        devices: subset,
                        result,
                    });
                    return Some(());
                }
            }
            None // the model meets the SLO nowhere: the fleet is infeasible
        }
        FleetObjective::MaxAggregateThroughput => {
            // Best feasible solo on a free device.
            let best_solo = free
                .iter()
                .filter_map(|&d| solo[mi][d].as_ref().map(|r| (r.throughput, d, r.clone())))
                .max_by(|a, b| {
                    a.0.partial_cmp(&b.0)
                        .unwrap_or(std::cmp::Ordering::Equal)
                        .then(b.1.cmp(&a.1))
                });
            if let Some((_, d, result)) = best_solo {
                placements.push(FleetPlacement::Solo { model: mi, device: d, result });
                return Some(());
            }
            // No single free board fits it: smallest feasible shard, best θ.
            for k in 2..=free.len() {
                let subsets = combinations(&free, k);
                let evals = parallel_cases(&subsets, |_, subset| {
                    let devs: Vec<Device> = subset.iter().map(|&d| devices[d].clone()).collect();
                    cache.explore_partitioned(&networks[mi], &devs, None, cfg).0
                });
                let best = subsets
                    .iter()
                    .zip(evals)
                    .filter_map(|(subset, r)| r.map(|r| (r.throughput, subset.clone(), r)))
                    .max_by(|a, b| {
                        a.0.partial_cmp(&b.0)
                            .unwrap_or(std::cmp::Ordering::Equal)
                            .then(b.1.cmp(&a.1))
                    });
                if let Some((_, subset, result)) = best {
                    placements.push(FleetPlacement::Sharded {
                        model: mi,
                        devices: subset,
                        result,
                    });
                    return Some(());
                }
            }
            // No free board works (or none are left): co-locate onto the
            // group with the best marginal aggregate θ.
            if let Some((at, models, device, result)) =
                best_colocate(cache, networks, devices, cfg, mi, placements, |grown| {
                    Some(grown.aggregate_throughput())
                })
            {
                placements[at] = FleetPlacement::Colocated { models, device, result };
                return Some(());
            }
            None
        }
    }
}

/// Evaluate growing every colocatable group (a solo placement or an existing
/// co-location — a sharded chain cannot take tenants) by model `mi`, scored
/// by `score` (`None` = disqualified). Returns the winning
/// `(placement index, grown model list, device, result)`; ties go to the
/// lowest device index. Group evaluations fan across cores.
fn best_colocate(
    cache: &DesignCache,
    networks: &[Network],
    devices: &[Device],
    cfg: &DseConfig,
    mi: usize,
    placements: &[FleetPlacement],
    score: impl Fn(&ColocatedResult) -> Option<f64>,
) -> Option<(usize, Vec<usize>, usize, ColocatedResult)> {
    let groups: Vec<(usize, Vec<usize>, usize)> = placements
        .iter()
        .enumerate()
        .filter_map(|(at, p)| match p {
            FleetPlacement::Solo { model, device, .. } => Some((at, vec![*model], *device)),
            FleetPlacement::Colocated { models, device, .. } => {
                Some((at, models.clone(), *device))
            }
            FleetPlacement::Sharded { .. } => None,
        })
        .collect();
    let evals = parallel_cases(&groups, |_, (_, models, device)| {
        let mut tenants: Vec<Network> = models.iter().map(|&i| networks[i].clone()).collect();
        tenants.push(networks[mi].clone());
        cache.explore_colocated(&tenants, &devices[*device], cfg).0
    });
    groups
        .into_iter()
        .zip(evals)
        .filter_map(|((at, mut models, device), r)| {
            let r = r?;
            let s = score(&r)?;
            models.push(mi);
            Some((s, at, models, device, r))
        })
        .max_by(|a, b| {
            a.0.partial_cmp(&b.0)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(b.3.cmp(&a.3)) // tie: lowest device index
        })
        .map(|(_, at, models, device, r)| (at, models, device, r))
}

/// MaxAggregateThroughput improvement pass: while free devices remain, widen
/// the lowest-θ solo/sharded placement onto one more free device (best
/// extension wins); stop as soon as widening no longer improves its θ.
fn improve(
    cache: &DesignCache,
    networks: &[Network],
    devices: &[Device],
    cfg: &DseConfig,
    placements: &mut [FleetPlacement],
) {
    loop {
        let free = free_devices(placements, devices.len());
        if free.is_empty() {
            return;
        }
        // The slowest single-model placement is the one more silicon helps.
        let slowest = placements
            .iter()
            .enumerate()
            .filter(|(_, p)| !matches!(p, FleetPlacement::Colocated { .. }))
            .min_by(|a, b| {
                a.1.throughput()
                    .partial_cmp(&b.1.throughput())
                    .unwrap_or(std::cmp::Ordering::Equal)
            });
        let Some((at, current)) = slowest else { return };
        let (model, mut chain, old_theta) = match current {
            FleetPlacement::Solo { model, device, result } => {
                (*model, vec![*device], result.throughput)
            }
            FleetPlacement::Sharded { model, devices, result } => {
                (*model, devices.clone(), result.throughput)
            }
            FleetPlacement::Colocated { .. } => unreachable!("filtered above"),
        };
        let candidates: Vec<Vec<usize>> = free
            .iter()
            .map(|&f| {
                let mut ext = chain.clone();
                ext.push(f);
                ext.sort_unstable(); // chain order = pool order: deterministic
                ext
            })
            .collect();
        let evals = parallel_cases(&candidates, |_, ext| {
            let devs: Vec<Device> = ext.iter().map(|&d| devices[d].clone()).collect();
            cache.explore_partitioned(&networks[model], &devs, None, cfg).0
        });
        let best = candidates
            .into_iter()
            .zip(evals)
            .filter_map(|(ext, r)| r.map(|r| (r.throughput, ext, r)))
            .max_by(|a, b| {
                a.0.partial_cmp(&b.0)
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(b.1.cmp(&a.1))
            });
        match best {
            Some((theta, ext, result)) if theta > old_theta => {
                chain = ext;
                placements[at] =
                    FleetPlacement::Sharded { model, devices: chain, result };
            }
            _ => return, // widening the bottleneck no longer helps
        }
    }
}

/// Fold committed placements into the result record.
fn finish(placements: Vec<FleetPlacement>, objective: FleetObjective) -> FleetResult {
    let mut used = std::collections::HashSet::new();
    for p in &placements {
        used.extend(p.device_indices());
    }
    let aggregate_throughput = placements.iter().map(FleetPlacement::throughput).sum();
    FleetResult { devices_used: used.len(), aggregate_throughput, placements, objective }
}

/// All k-element subsets of `pool`, lexicographic, preserving pool order
/// inside each subset (pool is ascending, so subsets are chains in pool
/// order). Fleet pools are small (a handful of boards), so the C(|pool|, k)
/// blow-up stays trivial.
fn combinations(pool: &[usize], k: usize) -> Vec<Vec<usize>> {
    let n = pool.len();
    let mut out = Vec::new();
    if k == 0 || k > n {
        return out;
    }
    let mut idx: Vec<usize> = (0..k).collect();
    loop {
        out.push(idx.iter().map(|&i| pool[i]).collect());
        let mut i = k as isize - 1;
        while i >= 0 && idx[i as usize] == n - k + i as usize {
            i -= 1;
        }
        if i < 0 {
            return out;
        }
        let i = i as usize;
        idx[i] += 1;
        for j in i + 1..k {
            idx[j] = idx[j - 1] + 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::{colocate, partition, run};
    use crate::ir::Quant;
    use crate::models;

    fn cache() -> DesignCache {
        DesignCache::new()
    }

    #[test]
    fn combinations_are_lexicographic_and_complete() {
        let pool = [1, 3, 5, 7];
        let c2 = combinations(&pool, 2);
        assert_eq!(c2, vec![
            vec![1, 3], vec![1, 5], vec![1, 7],
            vec![3, 5], vec![3, 7], vec![5, 7],
        ]);
        assert_eq!(combinations(&pool, 4), vec![vec![1, 3, 5, 7]]);
        assert!(combinations(&pool, 0).is_empty());
        assert!(combinations(&pool, 5).is_empty());
    }

    #[test]
    fn empty_inputs_are_infeasible() {
        let cfg = DseConfig::default();
        let net = models::toy_cnn(Quant::W8A8);
        let dev = Device::zcu102();
        assert!(fleet_in(&cache(), &[], &[dev.clone()], FleetObjective::MaxAggregateThroughput, &cfg)
            .is_none());
        assert!(fleet_in(&cache(), &[net], &[], FleetObjective::MaxAggregateThroughput, &cfg)
            .is_none());
    }

    #[test]
    fn one_by_one_matches_plain_dse() {
        let net = models::toy_cnn(Quant::W8A8);
        let dev = Device::zcu102();
        let cfg = DseConfig::default();
        let r = fleet_in(
            &cache(),
            std::slice::from_ref(&net),
            std::slice::from_ref(&dev),
            FleetObjective::MaxAggregateThroughput,
            &cfg,
        )
        .unwrap();
        assert_eq!(r.placements.len(), 1);
        assert_eq!(r.devices_used, 1);
        let direct = run(&net, &dev, &cfg).unwrap();
        match &r.placements[0] {
            FleetPlacement::Solo { model: 0, device: 0, result } => {
                assert_eq!(result.design.cfgs, direct.design.cfgs);
                assert_eq!(result.design.off_bits, direct.design.off_bits);
                assert_eq!(result.throughput, direct.throughput);
            }
            other => panic!("expected Solo, got {other:?}"),
        }
        assert_eq!(r.aggregate_throughput, direct.throughput);
    }

    #[test]
    fn one_by_m_matches_partition_of_the_full_chain() {
        let net = models::resnet18(Quant::W4A5);
        let devs = [Device::zcu102(), Device::zcu102()];
        let cfg = DseConfig::default();
        let r = fleet_in(
            &cache(),
            std::slice::from_ref(&net),
            &devs,
            FleetObjective::MaxAggregateThroughput,
            &cfg,
        )
        .unwrap();
        let direct = partition::partition(&net, &devs, &cfg).unwrap();
        match &r.placements[0] {
            FleetPlacement::Sharded { model: 0, devices, result } => {
                assert_eq!(devices, &[0, 1]);
                assert_eq!(result.cuts, direct.cuts);
                assert_eq!(result.throughput, direct.throughput);
            }
            other => panic!("expected Sharded, got {other:?}"),
        }
    }

    #[test]
    fn n_by_one_matches_colocate() {
        let nets = [models::resnet18(Quant::W4A5), models::squeezenet(Quant::W8A8)];
        let dev = Device::zcu102();
        let cfg = DseConfig::default();
        let r = fleet_in(
            &cache(),
            &nets,
            std::slice::from_ref(&dev),
            FleetObjective::MaxAggregateThroughput,
            &cfg,
        )
        .unwrap();
        let direct = colocate::colocate(&nets, &dev, &cfg).unwrap();
        match &r.placements[0] {
            FleetPlacement::Colocated { models, device: 0, result } => {
                assert_eq!(models, &[0, 1]);
                assert_eq!(result.tenants.len(), direct.tenants.len());
                for (a, b) in result.tenants.iter().zip(&direct.tenants) {
                    assert_eq!(a.share, b.share);
                    assert_eq!(a.result.throughput, b.result.throughput);
                }
            }
            other => panic!("expected Colocated, got {other:?}"),
        }
    }

    #[test]
    fn two_models_two_boards_go_solo_under_max_aggregate() {
        let nets = [models::resnet18(Quant::W4A5), models::squeezenet(Quant::W8A8)];
        let devs = [Device::zcu102(), Device::zc706()];
        let cfg = DseConfig::default();
        let c = cache();
        let r = fleet_in(&c, &nets, &devs, FleetObjective::MaxAggregateThroughput, &cfg).unwrap();
        assert_eq!(r.placements.len(), 2, "{:?}", r.placements);
        assert_eq!(r.devices_used, 2);
        let mut on = [false; 2];
        for p in &r.placements {
            match p {
                FleetPlacement::Solo { device, .. } => on[*device] = true,
                other => panic!("expected two Solo placements, got {other:?}"),
            }
        }
        assert!(on[0] && on[1], "each board carries one model");
        // aggregate is the placement sum, and every model is served once
        let sum: f64 = r.placements.iter().map(FleetPlacement::throughput).sum();
        assert_eq!(r.aggregate_throughput, sum);
        assert!(r.placement_of(0).is_some() && r.placement_of(1).is_some());
    }

    #[test]
    fn min_devices_colocates_under_a_loose_slo() {
        let nets = [models::resnet18(Quant::W4A5), models::squeezenet(Quant::W8A8)];
        let devs = [Device::zcu102(), Device::zcu102()];
        let cfg = DseConfig::default();
        let r = fleet_in(
            &cache(),
            &nets,
            &devs,
            FleetObjective::MinDevicesAtSlo { p99_ms: 1e9 },
            &cfg,
        )
        .unwrap();
        // a forgiving SLO lets both tenants share one board
        assert_eq!(r.devices_used, 1, "{:?}", r.placements);
        assert_eq!(r.placements.len(), 1);
        assert!(matches!(&r.placements[0], FleetPlacement::Colocated { models, .. }
            if models.len() == 2));
    }

    #[test]
    fn min_devices_unmeetable_slo_is_infeasible() {
        let nets = [models::resnet18(Quant::W4A5), models::squeezenet(Quant::W8A8)];
        let devs = [Device::zcu102(), Device::zcu102()];
        let cfg = DseConfig::default();
        let r = fleet_in(
            &cache(),
            &nets,
            &devs,
            FleetObjective::MinDevicesAtSlo { p99_ms: 1e-9 },
            &cfg,
        );
        assert!(r.is_none(), "no deployment can promise a sub-nanosecond p99");
    }

    #[test]
    fn search_is_deterministic() {
        let nets = [models::resnet18(Quant::W4A5), models::squeezenet(Quant::W8A8)];
        let devs = [Device::zcu102(), Device::zc706()];
        let cfg = DseConfig::default();
        let a = fleet_in(&cache(), &nets, &devs, FleetObjective::MaxAggregateThroughput, &cfg)
            .unwrap();
        let b = fleet_in(&cache(), &nets, &devs, FleetObjective::MaxAggregateThroughput, &cfg)
            .unwrap();
        assert_eq!(a.placements.len(), b.placements.len());
        assert_eq!(a.aggregate_throughput, b.aggregate_throughput);
        for (pa, pb) in a.placements.iter().zip(&b.placements) {
            assert_eq!(pa.model_indices(), pb.model_indices());
            assert_eq!(pa.device_indices(), pb.device_indices());
            assert_eq!(pa.throughput(), pb.throughput());
        }
    }

    #[test]
    fn slo_metric_floors_at_latency_plus_service_period() {
        assert_eq!(slo_metric(10.0, 100.0), 10.0 + 10.0);
        assert!(slo_metric(10.0, 0.0).is_infinite());
        assert!(slo_metric(5.0, 1e9) > 5.0);
    }
}
