//! Stochastic DSE baselines: random search and simulated annealing.
//!
//! The paper's contribution is the *greedy* Algorithm 1; these strategies
//! exist to quantify how much solution quality the greedy heuristic gives up
//! (ablation bench `dse_strategies`). Both explore the compute-allocation
//! space (unroll factors per layer) and delegate memory feasibility to the
//! paper's own `ALLOCATE_MEMORY` — the memory sub-problem is what the greedy
//! ΔB criterion already solves near-optimally (see `exhaustive.rs`), so the
//! interesting search space is the unroll assignment.
//!
//! §Perf: proposals run as undo-log trials on a single working design
//! (bit-exact rollback) instead of cloning the full `Design` per sample,
//! and the legal-unroll sets come from the memoized divisor cache.

use super::{allocate_memory, run as greedy_run, Design, DseConfig, DseResult};
use crate::ce::divisors_cached;
use crate::device::Device;
use crate::ir::Network;
use crate::util::XorShift64;

/// Search strategy selector.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Strategy {
    /// Paper Algorithm 1 (the default toolflow path).
    Greedy,
    /// Uniform random sampling of unroll assignments.
    Random { samples: usize, seed: u64 },
    /// Simulated annealing over single-layer unroll moves.
    Anneal { iters: usize, t0: f64, seed: u64 },
}

/// Run the selected strategy end-to-end.
pub fn run_with_strategy(
    network: &Network,
    device: &Device,
    cfg: &DseConfig,
    strategy: Strategy,
) -> Option<DseResult> {
    match strategy {
        Strategy::Greedy => greedy_run(network, device, cfg),
        Strategy::Random { samples, seed } => random_search(network, device, cfg, samples, seed),
        Strategy::Anneal { iters, t0, seed } => anneal(network, device, cfg, iters, t0, seed),
    }
}

/// Evaluate one design candidate: re-fit memory, check constraints, and
/// score by pipeline throughput. Returns `None` when infeasible.
fn evaluate(design: &mut Design, device: &Device, cfg: &DseConfig) -> Option<f64> {
    if !allocate_memory(design, device, cfg) {
        return None;
    }
    if !design.total_area().fits(device) {
        return None;
    }
    if design.total_bandwidth() > device.bandwidth_bps * cfg.bw_margin {
        return None;
    }
    Some(design.min_throughput())
}

fn result_from(design: Design) -> DseResult {
    let throughput = design.min_throughput();
    DseResult {
        throughput,
        latency_ms: design.latency_ms(1),
        area: design.total_area(),
        bandwidth_bps: design.total_bandwidth(),
        iterations: 0,
        design,
    }
}

/// Legal unroll values of layer `l` in each dimension, as (dimension tag,
/// divisor slice) pairs in a fixed-capacity buffer (no per-call allocation;
/// the divisor sets come from the memoized cache).
fn dims_of(design: &Design, l: usize) -> ([(u8, &'static [u32]); 3], usize) {
    let layer = &design.network.layers[l];
    let k2 = layer.kernel() * layer.kernel();
    let mut dims: [(u8, &'static [u32]); 3] = [(0, &[]); 3];
    let mut n = 0;
    if k2 > 1 {
        dims[n] = (0u8, divisors_cached(k2));
        n += 1;
    }
    if layer.has_weights() && layer.c_out > 1 {
        dims[n] = (1, divisors_cached(layer.c_out));
        n += 1;
    }
    if layer.c_per_group() > 1 {
        dims[n] = (2, divisors_cached(layer.c_per_group()));
        n += 1;
    }
    (dims, n)
}

fn set_dim(design: &mut Design, l: usize, which: u8, value: u32) {
    design.record_layer(l);
    match which {
        0 => design.cfgs[l].kp = value,
        1 => design.cfgs[l].fp = value,
        _ => design.cfgs[l].cp = value,
    }
    let n = design.cfgs[l].frag.n;
    design.set_fragmentation(l, n);
}

/// Random search: `samples` independent draws. Each draw picks, per layer, a
/// random legal unroll in every dimension, biased toward small values (the
/// area constraint rejects most large assignments on real devices — the
/// bias keeps the accept rate useful without excluding big designs).
pub fn random_search(
    network: &Network,
    device: &Device,
    cfg: &DseConfig,
    samples: usize,
    seed: u64,
) -> Option<DseResult> {
    let mut rng = XorShift64::new(seed);
    let mut work = Design::initialize(network, device);
    let mut best: Option<Design> = None;
    let mut best_theta = 0.0;

    for _ in 0..samples {
        work.begin_trial();
        for l in 0..work.len() {
            let (dims, ndims) = dims_of(&work, l);
            for &(which, vals) in &dims[..ndims] {
                // squared-uniform index biases toward the small end
                let u = rng.unit();
                let idx = ((u * u) * vals.len() as f64) as usize;
                set_dim(&mut work, l, which, vals[idx.min(vals.len() - 1)]);
            }
        }
        if let Some(theta) = evaluate(&mut work, device, cfg) {
            if theta > best_theta {
                best_theta = theta;
                best = Some(work.snapshot());
            }
        }
        // every sample starts from the pristine all-serial design
        work.rollback_trial();
    }
    best.map(result_from)
}

/// Simulated annealing: starts from the feasible all-serial design, proposes
/// single-(layer, dimension) unroll changes, accepts by Metropolis on the
/// log-throughput gap with geometric cooling.
pub fn anneal(
    network: &Network,
    device: &Device,
    cfg: &DseConfig,
    iters: usize,
    t0: f64,
    seed: u64,
) -> Option<DseResult> {
    let mut rng = XorShift64::new(seed);
    let mut cur = Design::initialize(network, device);
    let mut cur_theta = evaluate(&mut cur, device, cfg)?;
    let mut best = cur.clone();
    let mut best_theta = cur_theta;

    let t_end = t0 * 1e-3;
    for step in 0..iters {
        // cooling schedule: geometric from t0 to t0/1000
        let frac = step as f64 / iters.max(1) as f64;
        let temp = t0 * (t_end / t0).powf(frac);

        let l = rng.below(cur.len());
        let (dims, ndims) = dims_of(&cur, l);
        if ndims == 0 {
            continue;
        }
        let (which, vals) = dims[rng.below(ndims)];
        let cur_val = match which {
            0 => cur.cfgs[l].kp,
            1 => cur.cfgs[l].fp,
            _ => cur.cfgs[l].cp,
        };
        // neighbourhood move: adjacent divisor up or down
        let pos = vals.iter().position(|&v| v == cur_val).unwrap_or(0);
        let next_pos = if rng.unit() < 0.6 { pos + 1 } else { pos.saturating_sub(1) };
        if next_pos >= vals.len() || next_pos == pos {
            continue;
        }

        cur.begin_trial();
        set_dim(&mut cur, l, which, vals[next_pos]);
        let Some(theta) = evaluate(&mut cur, device, cfg) else {
            cur.rollback_trial();
            continue; // infeasible proposal
        };
        // Metropolis on relative throughput change
        let delta = (theta / cur_theta).ln();
        if delta >= 0.0 || rng.unit() < (delta / temp).exp() {
            cur.commit_trial();
            cur_theta = theta;
            if cur_theta > best_theta {
                best_theta = cur_theta;
                best = cur.clone();
            }
        } else {
            cur.rollback_trial();
        }
    }
    Some(result_from(best))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::Quant;
    use crate::models;

    fn setup() -> (Network, Device, DseConfig) {
        (models::toy_cnn(Quant::W8A8), Device::zcu102(), DseConfig::default())
    }

    #[test]
    fn random_search_finds_feasible_designs() {
        let (net, dev, cfg) = setup();
        let r = random_search(&net, &dev, &cfg, 50, 1).expect("some feasible sample");
        assert!(r.area.fits(&dev));
        assert!(r.throughput > 0.0);
        r.design.assert_aggregates_consistent();
    }

    #[test]
    fn random_search_deterministic_per_seed() {
        let (net, dev, cfg) = setup();
        let a = random_search(&net, &dev, &cfg, 30, 9).unwrap();
        let b = random_search(&net, &dev, &cfg, 30, 9).unwrap();
        assert_eq!(a.throughput, b.throughput);
        let c = random_search(&net, &dev, &cfg, 30, 10).unwrap();
        // different seed explores differently (identical only by coincidence;
        // this seed pair diverges)
        assert_ne!(a.throughput, c.throughput);
    }

    #[test]
    fn anneal_improves_over_serial_start() {
        let (net, dev, cfg) = setup();
        let serial = Design::initialize(&net, &dev).min_throughput();
        let r = anneal(&net, &dev, &cfg, 400, 0.5, 3).unwrap();
        assert!(
            r.throughput > serial * 3.0,
            "anneal {} vs serial {serial}",
            r.throughput
        );
        assert!(r.area.fits(&dev));
        r.design.assert_aggregates_consistent();
    }

    #[test]
    fn greedy_beats_or_matches_cheap_random() {
        // 30 random samples should not outperform the paper's greedy: the
        // greedy exploits the bottleneck structure random sampling ignores.
        let (net, dev, cfg) = setup();
        let g = greedy_run(&net, &dev, &cfg).unwrap();
        let r = random_search(&net, &dev, &cfg, 30, 5).unwrap();
        assert!(
            g.throughput >= r.throughput * 0.9,
            "greedy {} vs random {}",
            g.throughput,
            r.throughput
        );
    }

    #[test]
    fn strategy_selector_dispatches() {
        let (net, dev, cfg) = setup();
        for s in [
            Strategy::Greedy,
            Strategy::Random { samples: 10, seed: 1 },
            Strategy::Anneal { iters: 50, t0: 0.5, seed: 1 },
        ] {
            let r = run_with_strategy(&net, &dev, &cfg, s).unwrap();
            assert!(r.throughput > 0.0, "{s:?}");
        }
    }

    #[test]
    fn constraints_hold_on_memory_tight_device() {
        let (_, _, cfg) = setup();
        let net = models::resnet18(Quant::W4A5);
        let dev = Device::zc706();
        for s in [
            Strategy::Random { samples: 20, seed: 2 },
            Strategy::Anneal { iters: 150, t0: 0.5, seed: 2 },
        ] {
            if let Some(r) = run_with_strategy(&net, &dev, &cfg, s) {
                assert!(r.area.fits(&dev), "{s:?}");
                assert!(r.bandwidth_bps <= dev.bandwidth_bps * 1.0001, "{s:?}");
            }
        }
    }
}
