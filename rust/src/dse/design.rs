//! Mutable design state shared by the DSE phases: one [`CeConfig`] per
//! layer, with cached per-layer model evaluations *and* running aggregates so
//! the greedy loops stay cheap.
//!
//! §Perf: the DSE inner loop queries `total_area`/`mem_blocks`/
//! `total_bandwidth`/`latency_ms` after every proposal. The seed recomputed
//! each as an O(L) reduction; they are now O(1) reads of aggregates that
//! [`Design::refresh`] maintains incrementally (replace layer `i`'s old
//! contribution with its new one). Trials are likewise no longer
//! clone-evaluate-swap: [`Design::begin_trial`] opens an undo log that
//! snapshots each layer on first touch, and [`Design::rollback_trial`]
//! restores the exact (bit-identical) pre-trial state.

use crate::ce::{self, Area, CeConfig, Fragmentation};
use crate::device::Device;
use crate::ir::Network;

/// Per-layer snapshot taken on first mutation inside a trial.
#[derive(Debug, Clone)]
struct LayerSnap {
    cfg: CeConfig,
    off_bits: u64,
    cycles: u64,
    fill: u64,
    area: Area,
    beta: f64,
    wterm: f64,
}

/// Undo log of one open trial: global aggregate snapshot plus first-touch
/// per-layer snapshots.
#[derive(Debug, Clone)]
struct TrialLog {
    slowest_cache: usize,
    area_total: Area,
    fill_total: u64,
    wsum: f64,
    streaming_count: usize,
    layers: Vec<(usize, LayerSnap)>,
}

/// A complete accelerator design: the network plus a CE configuration per
/// layer, evaluated against the analytic models.
///
/// The network is behind an `Arc`: cloning a design (still used for "best so
/// far" bookkeeping in the stochastic strategies) must not deep-copy 50+
/// layers of `String`-named metadata (§Perf).
#[derive(Debug, Clone)]
pub struct Design {
    pub network: std::sync::Arc<Network>,
    pub clk_comp_mhz: f64,
    pub cfgs: Vec<CeConfig>,
    /// Bits of each layer's weights evicted to off-chip storage. This is the
    /// geometry-independent invariant: when unrolling changes the word
    /// width, the evicted *bits* stay put and the word counts are re-derived.
    pub off_bits: Vec<u64>,
    // --- caches, refreshed per-layer on mutation ---
    cycles: Vec<u64>,
    fills: Vec<u64>,
    areas: Vec<Area>,
    betas: Vec<f64>,
    /// Per-layer `cycles_l · β_l` — the numerator terms of the Eq. 6
    /// bandwidth sum (see [`Design::total_weight_bandwidth`]).
    wterms: Vec<f64>,
    /// Per-layer streaming flag mirror of `cfgs[i].frag.is_streaming()`,
    /// kept so `streaming_count` can be maintained in O(1).
    streaming: Vec<bool>,
    /// Cached index of the slowest layer (§Perf: `slowest()` was O(L) and
    /// sat inside `slowdown()`, making every `total_bandwidth()` O(L²) —
    /// the DSE inner loop's dominant term on 50+-layer networks).
    slowest_cache: usize,
    /// Cached `max_l ĥ_l·ŵ_l` — the network-constant factor of the Eq. 10
    /// repeat target (`r_target = batch · max_pixels`), hoisted out of the
    /// per-candidate burst-balance loops (§Perf).
    max_pixels: u64,
    // --- running aggregates (O(1) queries; §Perf) ---
    /// `Σ_l a_l` — total area over all CEs.
    area_total: Area,
    /// `Σ_l fill_l` — total pipeline-fill cycles.
    fill_total: u64,
    /// `Σ_l cycles_l · β_l`. Dividing by the bottleneck's cycle count gives
    /// `Σ_l s_l β_l` exactly (the common `1/cycles_max` factor of every
    /// slowdown is hoisted out of the sum).
    wsum: f64,
    /// Number of layers currently streaming weights from off-chip.
    streaming_count: usize,
    // --- trial/undo machinery ---
    /// Open undo log, if a trial is in progress.
    txn: Option<TrialLog>,
    /// Persistent first-touch scratch (all `false` outside trials), kept on
    /// the design so trials allocate nothing in steady state.
    touched: Vec<bool>,
}

impl Design {
    /// Algorithm 1 INITIALIZE: unroll factors all 1, all weights on-chip.
    pub fn initialize(network: &Network, device: &Device) -> Design {
        let n = network.layers.len();
        let mut d = Design {
            network: std::sync::Arc::new(network.clone()),
            clk_comp_mhz: device.clk_comp_mhz,
            cfgs: network.layers.iter().map(CeConfig::initial).collect(),
            off_bits: vec![0; n],
            cycles: vec![0; n],
            fills: vec![0; n],
            areas: vec![Area::default(); n],
            betas: vec![0.0; n],
            wterms: vec![0.0; n],
            streaming: vec![false; n],
            slowest_cache: 0,
            max_pixels: network
                .layers
                .iter()
                .map(|l| l.h_out() as u64 * l.w_out() as u64)
                .max()
                .unwrap_or(1),
            area_total: Area::default(),
            fill_total: 0,
            wsum: 0.0,
            streaming_count: 0,
            txn: None,
            touched: vec![false; n],
        };
        for i in 0..n {
            d.refresh(i);
        }
        d
    }

    /// `max_l ĥ_l·ŵ_l` over the network (constant per design).
    pub fn max_pixels(&self) -> u64 {
        self.max_pixels
    }

    pub fn len(&self) -> usize {
        self.cfgs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cfgs.is_empty()
    }

    // --- trial transactions -------------------------------------------------

    /// Open an undo log: every layer mutation until [`Design::commit_trial`]
    /// or [`Design::rollback_trial`] snapshots its pre-trial state on first
    /// touch. Replaces the clone-evaluate-swap pattern of the greedy and
    /// stochastic searches (§Perf: a full `Design` clone per proposal was
    /// the second-largest cost of `allocate_compute` after the eviction
    /// rescans). Trials do not nest.
    pub fn begin_trial(&mut self) {
        debug_assert!(self.txn.is_none(), "trials do not nest");
        self.txn = Some(TrialLog {
            slowest_cache: self.slowest_cache,
            area_total: self.area_total,
            fill_total: self.fill_total,
            wsum: self.wsum,
            streaming_count: self.streaming_count,
            layers: Vec::new(),
        });
    }

    /// Keep the trial's mutations and close the log.
    pub fn commit_trial(&mut self) {
        if let Some(txn) = self.txn.take() {
            for (i, _) in &txn.layers {
                self.touched[*i] = false;
            }
        }
    }

    /// Restore the exact pre-trial state (bit-identical: snapshots are
    /// restored, not reverse-applied, so even the floating-point aggregates
    /// come back unchanged) and close the log.
    pub fn rollback_trial(&mut self) {
        let Some(txn) = self.txn.take() else { return };
        crate::telemetry::counters().dse_trial_rollbacks.incr();
        for (i, s) in txn.layers.into_iter().rev() {
            self.touched[i] = false;
            self.cfgs[i] = s.cfg;
            self.off_bits[i] = s.off_bits;
            self.cycles[i] = s.cycles;
            self.fills[i] = s.fill;
            self.areas[i] = s.area;
            self.betas[i] = s.beta;
            self.wterms[i] = s.wterm;
            self.streaming[i] = s.cfg.frag.is_streaming();
        }
        self.slowest_cache = txn.slowest_cache;
        self.area_total = txn.area_total;
        self.fill_total = txn.fill_total;
        self.wsum = txn.wsum;
        self.streaming_count = txn.streaming_count;
    }

    /// Is a trial currently open?
    pub fn trial_open(&self) -> bool {
        self.txn.is_some()
    }

    /// Clone the current state as a standalone design, discarding any open
    /// trial bookkeeping in the copy (the original's trial stays open). Used
    /// to capture "best so far" mid-search.
    pub fn snapshot(&self) -> Design {
        let mut d = self.clone();
        d.txn = None;
        for t in &mut d.touched {
            *t = false;
        }
        d
    }

    /// Record layer `i`'s pre-mutation state in the open trial log (no-op
    /// outside a trial). Must be called *before* the first mutation of
    /// `cfgs[i]` / `off_bits[i]` in a trial; [`Design::set_fragmentation`]
    /// does so itself, direct field writers (unroll moves) call this first.
    pub(crate) fn record_layer(&mut self, i: usize) {
        let Some(txn) = self.txn.as_mut() else { return };
        if self.touched[i] {
            return;
        }
        self.touched[i] = true;
        txn.layers.push((
            i,
            LayerSnap {
                cfg: self.cfgs[i],
                off_bits: self.off_bits[i],
                cycles: self.cycles[i],
                fill: self.fills[i],
                area: self.areas[i],
                beta: self.betas[i],
                wterm: self.wterms[i],
            },
        ));
    }

    // --- per-layer refresh --------------------------------------------------

    /// Recompute the cached model outputs for layer `i` and fold the change
    /// into the running aggregates. Must be called after any mutation of
    /// `cfgs[i]` or `off_bits[i]`.
    pub fn refresh(&mut self, i: usize) {
        let layer = &self.network.layers[i];
        let cfg = &self.cfgs[i];
        let old_cycles = self.cycles[i];
        let new_cycles = ce::eval_cycles(layer, cfg);
        let new_fill = ce::fill_cycles(layer, cfg);
        let new_area = ce::eval_area(layer, cfg);
        let new_beta = ce::eval_beta(layer, cfg, self.clk_comp_mhz);
        let new_wterm = new_cycles as f64 * new_beta;
        // replace layer i's contribution in each aggregate; skip the float
        // update entirely when the term is unchanged (the common case for
        // unroll moves on non-streaming layers, where both terms are 0.0) so
        // rounding residue only accumulates while eviction state changes
        self.fill_total = self.fill_total - self.fills[i] + new_fill;
        self.area_total = self.area_total - self.areas[i] + new_area;
        if new_wterm.to_bits() != self.wterms[i].to_bits() {
            self.wsum = self.wsum - self.wterms[i] + new_wterm;
        }
        let now_streaming = cfg.frag.is_streaming();
        if self.streaming[i] != now_streaming {
            self.streaming[i] = now_streaming;
            if now_streaming {
                self.streaming_count += 1;
            } else {
                self.streaming_count -= 1;
            }
        }
        self.cycles[i] = new_cycles;
        self.fills[i] = new_fill;
        self.areas[i] = new_area;
        self.betas[i] = new_beta;
        self.wterms[i] = new_wterm;
        // Pin the running float sum back to exact zero whenever the
        // streaming set empties: every term is exactly 0.0 then, and this
        // discards the ± rounding residue of long add/remove histories so
        // `total_weight_bandwidth()` is exactly 0 for all-on-chip designs.
        if self.streaming_count == 0 {
            self.wsum = 0.0;
        }
        // maintain the slowest-layer cache: O(1) unless the reigning
        // bottleneck itself just got faster, which forces a rescan
        if i == self.slowest_cache {
            if new_cycles < old_cycles {
                self.slowest_cache =
                    (0..self.len()).max_by_key(|&j| self.cycles[j]).unwrap_or(0);
            }
        } else if new_cycles > self.cycles[self.slowest_cache] {
            self.slowest_cache = i;
        }
    }

    /// Re-derive layer `i`'s fragmentation from its evicted bits and a
    /// fragment count `n`, then refresh caches.
    pub fn set_fragmentation(&mut self, i: usize, n: u32) {
        self.record_layer(i);
        let layer = &self.network.layers[i];
        let cfg = &self.cfgs[i];
        let m_dep = ce::eval_m_dep(layer, cfg);
        let m_wid = ce::eval_m_wid_bits(layer, cfg);
        let m_off = if m_wid == 0 { 0 } else { self.off_bits[i].div_ceil(m_wid).min(m_dep) };
        self.cfgs[i].frag = if m_off == 0 {
            Fragmentation::all_on_chip(m_dep)
        } else {
            Fragmentation::new(m_dep, m_off, n.max(1))
        };
        self.refresh(i);
    }

    // --- queries ------------------------------------------------------------

    /// Per-layer throughput θ_l in samples/s.
    pub fn throughput(&self, i: usize) -> f64 {
        self.clk_comp_mhz * 1e6 / self.cycles[i] as f64
    }

    /// Index of the slowest layer (Algorithm 1 SORT_BY θ, first element).
    /// O(1): maintained incrementally by [`Design::refresh`].
    pub fn slowest(&self) -> usize {
        self.slowest_cache
    }

    /// Pipeline throughput `min_l θ_l` (Eq. 6 objective).
    pub fn min_throughput(&self) -> f64 {
        self.throughput(self.slowest())
    }

    /// Slow-down factor `s_l = min θ / θ_l` (Eq. 7).
    pub fn slowdown(&self, i: usize) -> f64 {
        let max_cycles = self.cycles[self.slowest()] as f64;
        self.cycles[i] as f64 / max_cycles
    }

    /// Per-layer off-chip weight bandwidth demand `s_l · β_l` (bits/s).
    pub fn weight_bandwidth(&self, i: usize) -> f64 {
        self.slowdown(i) * self.betas[i]
    }

    /// Total weight-streaming bandwidth `Σ_l s_l β_l`. O(1): the cached
    /// `Σ_l cycles_l·β_l` divided by the bottleneck's cycle count (every
    /// slowdown shares the same `1/cycles_max` factor).
    pub fn total_weight_bandwidth(&self) -> f64 {
        self.wsum / self.cycles[self.slowest_cache] as f64
    }

    /// Activation I/O bandwidth `β_io` at the current pipeline rate.
    pub fn io_bandwidth(&self) -> f64 {
        self.network.beta_io(self.min_throughput())
    }

    /// Constraint left-hand side of Eq. 6: `β_io + Σ s_l β_l`. O(1).
    pub fn total_bandwidth(&self) -> f64 {
        self.io_bandwidth() + self.total_weight_bandwidth()
    }

    /// Total area over all CEs. O(1): running aggregate.
    pub fn total_area(&self) -> Area {
        self.area_total
    }

    /// Total BRAM blocks consumed by weight memories + buffers + FIFOs —
    /// the quantity checked against the `A_mem` budget. O(1).
    pub fn mem_blocks(&self) -> u32 {
        self.area_total.bram.total()
    }

    /// Analytic single-batch latency in milliseconds: pipeline fill of every
    /// stage plus `batch` drains of the bottleneck stage. O(1).
    pub fn latency_ms(&self, batch: u64) -> f64 {
        let bottleneck = self.cycles[self.slowest()];
        (self.fill_total + batch * bottleneck) as f64 / (self.clk_comp_mhz * 1e6) * 1e3
    }

    /// Does any layer stream weights from off-chip? O(1).
    pub fn any_streaming(&self) -> bool {
        self.streaming_count > 0
    }

    /// Number of layers currently streaming. O(1).
    pub fn streaming_count(&self) -> usize {
        self.streaming_count
    }

    /// Indices of layers currently streaming (for burst balancing and the
    /// DMA schedule).
    pub fn streaming_layers(&self) -> Vec<usize> {
        self.streaming_layer_iter().collect()
    }

    /// Allocation-free variant of [`Design::streaming_layers`] for hot
    /// loops (§Perf: `rebalance_all` allocated a `Vec` per eviction).
    pub fn streaming_layer_iter(&self) -> impl Iterator<Item = usize> + '_ {
        self.streaming
            .iter()
            .enumerate()
            .filter_map(|(i, &s)| if s { Some(i) } else { None })
    }

    /// Fraction of the network's weight bits held off-chip (the y2-axis of
    /// Fig. 6), derived from the per-layer fragmentation geometry.
    pub fn offchip_weight_frac(&self) -> f64 {
        let total: u64 = self.network.layers.iter().map(|l| l.weight_bits()).sum();
        if total == 0 {
            return 0.0;
        }
        let off: f64 = self
            .network
            .layers
            .iter()
            .zip(&self.cfgs)
            .map(|(l, c)| {
                if l.has_weights() {
                    c.frag.off_chip_ratio() * l.weight_bits() as f64
                } else {
                    0.0
                }
            })
            .sum();
        off / total as f64
    }

    /// Weight-reuse repetition count `r_l = b·ĥ·ŵ·n` (Eq. 3).
    pub fn repeats(&self, i: usize, batch: u64) -> u64 {
        let l = &self.network.layers[i];
        batch * l.h_out() as u64 * l.w_out() as u64 * self.cfgs[i].frag.n as u64
    }

    pub fn area_of(&self, i: usize) -> Area {
        self.areas[i]
    }

    pub fn beta_of(&self, i: usize) -> f64 {
        self.betas[i]
    }

    pub fn cycles_of(&self, i: usize) -> u64 {
        self.cycles[i]
    }

    /// Debug/test oracle: recompute every aggregate from scratch and check
    /// it against the running caches. Integer aggregates must match exactly;
    /// the floating-point bandwidth sum within accumulation tolerance.
    pub fn assert_aggregates_consistent(&self) {
        let area: Area = self.areas.iter().copied().sum();
        assert_eq!(area, self.area_total, "area aggregate drifted");
        let fill: u64 = self.fills.iter().sum();
        assert_eq!(fill, self.fill_total, "fill aggregate drifted");
        let streaming = self.cfgs.iter().filter(|c| c.frag.is_streaming()).count();
        assert_eq!(streaming, self.streaming_count, "streaming count drifted");
        let wsum: f64 = (0..self.len()).map(|i| self.cycles[i] as f64 * self.betas[i]).sum();
        // The running sum accumulates one rounding step per replace; bound
        // the residue relative to the largest term ever plausibly involved
        // (the fresh sum is a lower bound on that scale within one eviction
        // phase; resets pin the cache back to exact zero).
        let tol = 1e-6 * wsum.abs().max(1.0);
        assert!(
            (wsum - self.wsum).abs() <= tol,
            "bandwidth aggregate drifted: cached {} vs fresh {}",
            self.wsum,
            wsum
        );
        let slowest_cycles = self.cycles.iter().copied().max().unwrap_or(0);
        assert_eq!(
            self.cycles[self.slowest_cache], slowest_cycles,
            "slowest-layer cache drifted"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::Quant;
    use crate::models;

    fn design() -> Design {
        Design::initialize(&models::toy_cnn(Quant::W8A8), &Device::zcu102())
    }

    #[test]
    fn initial_state_all_onchip() {
        let d = design();
        assert!(!d.any_streaming());
        assert_eq!(d.total_weight_bandwidth(), 0.0);
        assert!(d.total_bandwidth() > 0.0, "io bandwidth is never zero");
        d.assert_aggregates_consistent();
    }

    #[test]
    fn slowdown_of_slowest_is_one() {
        let d = design();
        let s = d.slowest();
        assert!((d.slowdown(s) - 1.0).abs() < 1e-12);
        for i in 0..d.len() {
            assert!(d.slowdown(i) <= 1.0 + 1e-12);
        }
    }

    #[test]
    fn eviction_preserved_across_unroll_change() {
        let mut d = design();
        // evict half of conv3 (index 2)
        let wid = ce::CeModel::new(&d.network.layers[2], d.cfgs[2], d.clk_comp_mhz).m_wid_bits();
        let dep = ce::CeModel::new(&d.network.layers[2], d.cfgs[2], d.clk_comp_mhz).m_dep();
        d.off_bits[2] = dep / 2 * wid;
        d.set_fragmentation(2, 4);
        let bits_before = d.cfgs[2].frag.m_off_dep() as f64
            * ce::CeModel::new(&d.network.layers[2], d.cfgs[2], d.clk_comp_mhz).m_wid_bits() as f64;
        // now unroll and re-derive
        d.cfgs[2].cp = 4;
        d.set_fragmentation(2, 4);
        let wid2 = ce::CeModel::new(&d.network.layers[2], d.cfgs[2], d.clk_comp_mhz).m_wid_bits();
        let bits_after = d.cfgs[2].frag.m_off_dep() as f64 * wid2 as f64;
        let rel = (bits_after - bits_before).abs() / bits_before;
        assert!(rel < 0.05, "evicted bits drifted {rel}");
        d.assert_aggregates_consistent();
    }

    #[test]
    fn latency_decreases_with_parallelism() {
        let mut d = design();
        let before = d.latency_ms(1);
        let s = d.slowest();
        d.cfgs[s].cp = d.network.layers[s].c_per_group().min(4).max(1);
        d.set_fragmentation(s, 1);
        assert!(d.latency_ms(1) < before);
    }

    #[test]
    fn aggregates_track_arbitrary_mutations() {
        let mut d = design();
        for i in 0..d.len() {
            if d.network.layers[i].c_per_group() > 1 {
                d.cfgs[i].cp = 2;
            }
            d.set_fragmentation(i, 1);
            d.assert_aggregates_consistent();
        }
        // compare against a recomputed total
        let fresh: Area = (0..d.len()).map(|i| d.area_of(i)).sum();
        assert_eq!(fresh, d.total_area());
    }

    #[test]
    fn rollback_restores_bit_identical_state() {
        let mut d = design();
        let wid = ce::CeModel::new(&d.network.layers[2], d.cfgs[2], d.clk_comp_mhz).m_wid_bits();
        d.off_bits[2] = 64 * wid;
        d.set_fragmentation(2, 2);
        let area0 = d.total_area();
        let bw0 = d.total_bandwidth();
        let theta0 = d.min_throughput();
        let cfgs0 = d.cfgs.clone();
        let off0 = d.off_bits.clone();

        d.begin_trial();
        // mutate several layers through the sanctioned entry points
        for i in 0..d.len() {
            d.record_layer(i);
            if d.network.layers[i].c_out > 1 {
                d.cfgs[i].fp = d.network.layers[i].c_out.min(2);
            }
            d.set_fragmentation(i, 3);
        }
        assert!(d.trial_open());
        d.rollback_trial();

        assert_eq!(d.cfgs, cfgs0);
        assert_eq!(d.off_bits, off0);
        assert_eq!(d.total_area(), area0);
        assert!(d.total_bandwidth() == bw0, "bandwidth must restore bit-exactly");
        assert!(d.min_throughput() == theta0);
        d.assert_aggregates_consistent();
    }

    #[test]
    fn commit_keeps_trial_mutations() {
        let mut d = design();
        let before = d.min_throughput();
        d.begin_trial();
        let s = d.slowest();
        d.record_layer(s);
        d.cfgs[s].cp = d.network.layers[s].c_per_group().min(4).max(1);
        d.set_fragmentation(s, 1);
        d.commit_trial();
        assert!(d.min_throughput() > before);
        assert!(!d.trial_open());
        d.assert_aggregates_consistent();
    }

    #[test]
    fn snapshot_mid_trial_is_standalone() {
        let mut d = design();
        d.begin_trial();
        let s = d.slowest();
        d.record_layer(s);
        d.cfgs[s].cp = d.network.layers[s].c_per_group().min(4).max(1);
        d.set_fragmentation(s, 1);
        let snap = d.snapshot();
        d.rollback_trial();
        assert!(!snap.trial_open());
        assert!(snap.min_throughput() > d.min_throughput());
        snap.assert_aggregates_consistent();
    }
}
