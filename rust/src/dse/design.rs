//! Mutable design state shared by the DSE phases: one [`CeConfig`] per
//! layer, with cached per-layer model evaluations so the greedy loops stay
//! cheap (the caches are refreshed only for mutated layers).

use crate::ce::{self, Area, CeConfig, Fragmentation};
use crate::device::Device;
use crate::ir::Network;

/// A complete accelerator design: the network plus a CE configuration per
/// layer, evaluated against the analytic models.
///
/// The network is behind an `Arc`: the greedy DSE clones the design once
/// per trial iteration, and deep-copying 50+ layers of `String`-named
/// metadata dominated the clone cost (§Perf: 147 ms → 86 ms on
/// resnet50-zcu102 from this + the borrow-based model evaluation).
#[derive(Debug, Clone)]
pub struct Design {
    pub network: std::sync::Arc<Network>,
    pub clk_comp_mhz: f64,
    pub cfgs: Vec<CeConfig>,
    /// Bits of each layer's weights evicted to off-chip storage. This is the
    /// geometry-independent invariant: when unrolling changes the word
    /// width, the evicted *bits* stay put and the word counts are re-derived.
    pub off_bits: Vec<u64>,
    // --- caches, refreshed per-layer on mutation ---
    cycles: Vec<u64>,
    fills: Vec<u64>,
    areas: Vec<Area>,
    betas: Vec<f64>,
    /// Cached index of the slowest layer (§Perf: `slowest()` was O(L) and
    /// sat inside `slowdown()`, making every `total_bandwidth()` O(L²) —
    /// the DSE inner loop's dominant term on 50+-layer networks).
    slowest_cache: usize,
    /// Cached `max_l ĥ_l·ŵ_l` — the network-constant factor of the Eq. 10
    /// repeat target (`r_target = batch · max_pixels`), hoisted out of the
    /// per-candidate burst-balance loops (§Perf).
    max_pixels: u64,
}

impl Design {
    /// Algorithm 1 INITIALIZE: unroll factors all 1, all weights on-chip.
    pub fn initialize(network: &Network, device: &Device) -> Design {
        let n = network.layers.len();
        let mut d = Design {
            network: std::sync::Arc::new(network.clone()),
            clk_comp_mhz: device.clk_comp_mhz,
            cfgs: network.layers.iter().map(CeConfig::initial).collect(),
            off_bits: vec![0; n],
            cycles: vec![0; n],
            fills: vec![0; n],
            areas: vec![Area::default(); n],
            betas: vec![0.0; n],
            slowest_cache: 0,
            max_pixels: network
                .layers
                .iter()
                .map(|l| l.h_out() as u64 * l.w_out() as u64)
                .max()
                .unwrap_or(1),
        };
        for i in 0..n {
            d.refresh(i);
        }
        d
    }

    /// `max_l ĥ_l·ŵ_l` over the network (constant per design).
    pub fn max_pixels(&self) -> u64 {
        self.max_pixels
    }

    pub fn len(&self) -> usize {
        self.cfgs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cfgs.is_empty()
    }

    /// Recompute the cached model outputs for layer `i`. Must be called
    /// after any mutation of `cfgs[i]` or `off_bits[i]`.
    pub fn refresh(&mut self, i: usize) {
        let layer = &self.network.layers[i];
        let cfg = &self.cfgs[i];
        let old = self.cycles[i];
        self.cycles[i] = ce::eval_cycles(layer, cfg);
        self.fills[i] = ce::fill_cycles(layer, cfg);
        self.areas[i] = ce::eval_area(layer, cfg);
        self.betas[i] = ce::eval_beta(layer, cfg, self.clk_comp_mhz);
        // maintain the slowest-layer cache: O(1) unless the reigning
        // bottleneck itself just got faster, which forces a rescan
        if i == self.slowest_cache {
            if self.cycles[i] < old {
                self.slowest_cache =
                    (0..self.len()).max_by_key(|&j| self.cycles[j]).unwrap_or(0);
            }
        } else if self.cycles[i] > self.cycles[self.slowest_cache] {
            self.slowest_cache = i;
        }
    }

    /// Re-derive layer `i`'s fragmentation from its evicted bits and a
    /// fragment count `n`, then refresh caches.
    pub fn set_fragmentation(&mut self, i: usize, n: u32) {
        let layer = &self.network.layers[i];
        let cfg = &self.cfgs[i];
        let m_dep = ce::eval_m_dep(layer, cfg);
        let m_wid = ce::eval_m_wid_bits(layer, cfg);
        let m_off = if m_wid == 0 { 0 } else { self.off_bits[i].div_ceil(m_wid).min(m_dep) };
        self.cfgs[i].frag = if m_off == 0 {
            Fragmentation::all_on_chip(m_dep)
        } else {
            Fragmentation::new(m_dep, m_off, n.max(1))
        };
        self.refresh(i);
    }

    /// Per-layer throughput θ_l in samples/s.
    pub fn throughput(&self, i: usize) -> f64 {
        self.clk_comp_mhz * 1e6 / self.cycles[i] as f64
    }

    /// Index of the slowest layer (Algorithm 1 SORT_BY θ, first element).
    /// O(1): maintained incrementally by [`Design::refresh`].
    pub fn slowest(&self) -> usize {
        self.slowest_cache
    }

    /// Pipeline throughput `min_l θ_l` (Eq. 6 objective).
    pub fn min_throughput(&self) -> f64 {
        self.throughput(self.slowest())
    }

    /// Slow-down factor `s_l = min θ / θ_l` (Eq. 7).
    pub fn slowdown(&self, i: usize) -> f64 {
        let max_cycles = self.cycles[self.slowest()] as f64;
        self.cycles[i] as f64 / max_cycles
    }

    /// Per-layer off-chip weight bandwidth demand `s_l · β_l` (bits/s).
    pub fn weight_bandwidth(&self, i: usize) -> f64 {
        self.slowdown(i) * self.betas[i]
    }

    /// Total weight-streaming bandwidth `Σ_l s_l β_l`.
    pub fn total_weight_bandwidth(&self) -> f64 {
        (0..self.len()).map(|i| self.weight_bandwidth(i)).sum()
    }

    /// Activation I/O bandwidth `β_io` at the current pipeline rate.
    pub fn io_bandwidth(&self) -> f64 {
        self.network.beta_io(self.min_throughput())
    }

    /// Constraint left-hand side of Eq. 6: `β_io + Σ s_l β_l`.
    pub fn total_bandwidth(&self) -> f64 {
        self.io_bandwidth() + self.total_weight_bandwidth()
    }

    /// Total area over all CEs.
    pub fn total_area(&self) -> Area {
        self.areas.iter().copied().sum()
    }

    /// Total BRAM blocks consumed by weight memories + buffers + FIFOs —
    /// the quantity checked against the `A_mem` budget.
    pub fn mem_blocks(&self) -> u32 {
        self.areas.iter().map(|a| a.bram.total()).sum()
    }

    /// Analytic single-batch latency in milliseconds: pipeline fill of every
    /// stage plus `batch` drains of the bottleneck stage.
    pub fn latency_ms(&self, batch: u64) -> f64 {
        let fill: u64 = self.fills.iter().sum();
        let bottleneck = self.cycles[self.slowest()];
        (fill + batch * bottleneck) as f64 / (self.clk_comp_mhz * 1e6) * 1e3
    }

    /// Does any layer stream weights from off-chip?
    pub fn any_streaming(&self) -> bool {
        self.cfgs.iter().any(|c| c.frag.is_streaming())
    }

    /// Indices of layers currently streaming (for burst balancing and the
    /// DMA schedule).
    pub fn streaming_layers(&self) -> Vec<usize> {
        (0..self.len()).filter(|&i| self.cfgs[i].frag.is_streaming()).collect()
    }

    /// Weight-reuse repetition count `r_l = b·ĥ·ŵ·n` (Eq. 3).
    pub fn repeats(&self, i: usize, batch: u64) -> u64 {
        let l = &self.network.layers[i];
        batch * l.h_out() as u64 * l.w_out() as u64 * self.cfgs[i].frag.n as u64
    }

    pub fn area_of(&self, i: usize) -> Area {
        self.areas[i]
    }

    pub fn beta_of(&self, i: usize) -> f64 {
        self.betas[i]
    }

    pub fn cycles_of(&self, i: usize) -> u64 {
        self.cycles[i]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::Quant;
    use crate::models;

    fn design() -> Design {
        Design::initialize(&models::toy_cnn(Quant::W8A8), &Device::zcu102())
    }

    #[test]
    fn initial_state_all_onchip() {
        let d = design();
        assert!(!d.any_streaming());
        assert_eq!(d.total_weight_bandwidth(), 0.0);
        assert!(d.total_bandwidth() > 0.0, "io bandwidth is never zero");
    }

    #[test]
    fn slowdown_of_slowest_is_one() {
        let d = design();
        let s = d.slowest();
        assert!((d.slowdown(s) - 1.0).abs() < 1e-12);
        for i in 0..d.len() {
            assert!(d.slowdown(i) <= 1.0 + 1e-12);
        }
    }

    #[test]
    fn eviction_preserved_across_unroll_change() {
        let mut d = design();
        // evict half of conv3 (index 2)
        let wid = ce::CeModel::new(&d.network.layers[2], d.cfgs[2], d.clk_comp_mhz).m_wid_bits();
        let dep = ce::CeModel::new(&d.network.layers[2], d.cfgs[2], d.clk_comp_mhz).m_dep();
        d.off_bits[2] = dep / 2 * wid;
        d.set_fragmentation(2, 4);
        let bits_before = d.cfgs[2].frag.m_off_dep() as f64
            * ce::CeModel::new(&d.network.layers[2], d.cfgs[2], d.clk_comp_mhz).m_wid_bits() as f64;
        // now unroll and re-derive
        d.cfgs[2].cp = 4;
        d.set_fragmentation(2, 4);
        let wid2 = ce::CeModel::new(&d.network.layers[2], d.cfgs[2], d.clk_comp_mhz).m_wid_bits();
        let bits_after = d.cfgs[2].frag.m_off_dep() as f64 * wid2 as f64;
        let rel = (bits_after - bits_before).abs() / bits_before;
        assert!(rel < 0.05, "evicted bits drifted {rel}");
    }

    #[test]
    fn latency_decreases_with_parallelism() {
        let mut d = design();
        let before = d.latency_ms(1);
        let s = d.slowest();
        d.cfgs[s].cp = d.network.layers[s].c_per_group().min(4).max(1);
        d.set_fragmentation(s, 1);
        assert!(d.latency_ms(1) < before);
    }
}
