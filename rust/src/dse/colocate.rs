//! Multi-tenant co-location: plan N networks onto ONE device.
//!
//! The dual of [`super::partition`] (one network, many devices): here the
//! device's DSP/LUT/FF/BRAM and off-chip DMA bandwidth are split into
//! per-tenant budgets, each tenant runs the unchanged greedy DSE (paper
//! Algorithm 1) against its budget-clamped [`Device`] view
//! ([`Device::with_share`]), and a rebalancing loop moves budget from slack
//! tenants to the tenant with the worst bottleneck.
//!
//! Why this is tractable at all is AutoWS's own argument: the static burst
//! schedule makes off-chip bandwidth a *budgeted* resource (Eq. 5, Eq. 8–10),
//! so carving the DMA port into per-tenant bandwidth slices preserves each
//! tenant's stall-freedom proof — every tenant's schedule is feasible against
//! its slice, and the slices sum to at most the port
//! ([`crate::schedule::SharedDmaSchedule`] re-checks the composition).
//!
//! Search shape:
//!
//! 1. **Seed** shares proportionally to each tenant's weight footprint
//!    (weight bits — the quantity streaming actually moves).
//! 2. **Evaluate** every tenant's DSE on its view, fanned across cores via
//!    [`super::parallel_cases`].
//! 3. **Rebalance**: score each tenant by throughput *normalized to its solo
//!    run on the whole device* (raw fps would starve small models), then
//!    shift a slice of budget from the most-slack tenant to the worst one
//!    (an infeasible tenant is worst by definition). Keep the best outcome
//!    seen; stop after [`MAX_ROUNDS`] or when no donor has slack to spare.
//!
//! Floored views ([`Device::with_share`]) guarantee the invariant the
//! acceptance tests assert: summed per-tenant area/BRAM/bandwidth never
//! exceed the physical device. A single tenant gets the whole device
//! untouched, so the 1-tenant case is bit-identical to the single-device
//! DSE (golden-tested in `tests/colocated_deploy.rs`).

use super::{parallel_cases, run, DseConfig, DseResult};
use crate::ce::Area;
use crate::device::Device;
use crate::ir::Network;

/// Rebalancing rounds after the seeded evaluation.
const MAX_ROUNDS: usize = 10;

/// No tenant's share may be rebalanced below this floor.
const MIN_SHARE: f64 = 0.02;

/// Fraction of the donor's share one rebalancing step moves.
const TRANSFER_FRAC: f64 = 0.2;

/// One tenant of a co-located deployment: its budget share, the clamped
/// device view it was planned against, and its DSE outcome.
#[derive(Debug, Clone)]
pub struct TenantPlan {
    /// Tenant label (the network's name; the pipeline layer enforces
    /// uniqueness before serving).
    pub name: String,
    /// Fraction of the device budget this tenant holds (shares sum to 1).
    pub share: f64,
    /// The budget-clamped device view ([`Device::with_share`]) the DSE ran
    /// against — also the view its burst schedule must be derived from.
    pub view: Device,
    /// The tenant's DSE outcome on that view (its design embeds the
    /// tenant's network).
    pub result: DseResult,
    /// Throughput of the tenant's solo run on the whole device
    /// (normalization baseline of the joint objective).
    pub solo_throughput: f64,
}

impl TenantPlan {
    /// Throughput normalized to the tenant's solo run on the full device
    /// (1.0 = co-location costs this tenant nothing).
    pub fn norm_throughput(&self) -> f64 {
        if self.solo_throughput > 0.0 {
            self.result.throughput / self.solo_throughput
        } else {
            0.0
        }
    }
}

/// Outcome of a joint co-location search: one [`TenantPlan`] per network
/// plus the joint metrics.
#[derive(Debug, Clone)]
pub struct ColocatedResult {
    /// One plan per tenant, in input order.
    pub tenants: Vec<TenantPlan>,
    /// Worst tenant's normalized throughput (the joint objective).
    pub min_norm_throughput: f64,
    /// The rebalancing round whose outcome this is: 0 when the seeded split
    /// was kept, N when the N-th transfer produced the best score seen.
    pub rounds: usize,
}

impl ColocatedResult {
    /// Summed area across tenants — must fit the physical device
    /// (guaranteed by the floored views; re-asserted by tests).
    pub fn joint_area(&self) -> Area {
        self.tenants.iter().fold(Area::default(), |acc, t| acc + t.result.area)
    }

    /// Summed off-chip bandwidth demand across tenants, bits/s.
    pub fn joint_bandwidth_bps(&self) -> f64 {
        self.tenants.iter().map(|t| t.result.bandwidth_bps).sum()
    }

    /// Summed throughput across tenants, samples/s (capacity figure; each
    /// tenant serves its own request stream).
    pub fn aggregate_throughput(&self) -> f64 {
        self.tenants.iter().map(|t| t.result.throughput).sum()
    }
}

/// Seed shares proportional to weight footprint (weight bits), with every
/// share floored at [`MIN_SHARE`] (or `1/N` if smaller) and the total
/// summing to exactly 1: below-floor tenants are pinned AT the floor and
/// the remaining mass redistributes proportionally among the rest
/// (water-filling, at most N rounds). A plain clamp-then-normalize would
/// push clamped tenants back below the floor.
fn seed_shares(networks: &[Network]) -> Vec<f64> {
    let n = networks.len();
    let floor = MIN_SHARE.min(1.0 / n as f64);
    // zero-weight tenants count as one bit so they still seed a share
    let bits: Vec<f64> =
        networks.iter().map(|net| (net.stats().weight_bits as f64).max(1.0)).collect();
    let mut fixed = vec![false; n];
    let mut shares = vec![0.0; n];
    loop {
        let fixed_mass = fixed.iter().filter(|&&f| f).count() as f64 * floor;
        let free_bits: f64 =
            bits.iter().zip(&fixed).filter(|&(_, &f)| !f).map(|(b, _)| b).sum();
        let mut changed = false;
        for i in 0..n {
            shares[i] = if fixed[i] {
                floor
            } else {
                (1.0 - fixed_mass) * bits[i] / free_bits
            };
            if !fixed[i] && shares[i] < floor {
                // pin this tenant at the floor and redistribute the rest
                fixed[i] = true;
                changed = true;
            }
        }
        if !changed {
            // At least one tenant always stays unpinned (floor <= 1/N means
            // the proportional remainder cannot be below-floor everywhere),
            // so `free_bits` never hits zero and this terminates within N
            // rounds.
            return shares;
        }
    }
}

/// Evaluate every tenant on its share of the device (fanned across cores).
/// `memo` caches `(tenant, share)` evaluations within one search — a
/// rebalance round only changes two tenants' shares, so the other tenants'
/// (expensive) DSE runs replay from the memo instead of recomputing.
fn evaluate(
    networks: &[Network],
    device: &Device,
    shares: &[f64],
    cfg: &DseConfig,
    memo: &mut std::collections::HashMap<(usize, u64), (Device, Option<DseResult>)>,
) -> Vec<(Device, Option<DseResult>)> {
    let misses: Vec<(usize, f64)> = shares
        .iter()
        .enumerate()
        .filter(|&(i, s)| !memo.contains_key(&(i, s.to_bits())))
        .map(|(i, &s)| (i, s))
        .collect();
    let fresh = parallel_cases(&misses, |_, &(i, share)| {
        let view = device.with_share(share);
        let result = run(&networks[i], &view, cfg);
        (view, result)
    });
    for ((i, s), r) in misses.into_iter().zip(fresh) {
        memo.insert((i, s.to_bits()), r);
    }
    shares.iter().enumerate().map(|(i, s)| memo[&(i, s.to_bits())].clone()).collect()
}

/// Joint objective of one evaluation: `(feasible count, min normalized
/// throughput)` — compared lexicographically, so gaining a feasible tenant
/// always beats polishing throughput.
fn score(evals: &[(Device, Option<DseResult>)], solo: &[f64]) -> (usize, f64) {
    let mut feasible = 0;
    let mut min_norm = f64::INFINITY;
    for (i, (_, r)) in evals.iter().enumerate() {
        match r {
            Some(r) => {
                feasible += 1;
                let norm = if solo[i] > 0.0 { r.throughput / solo[i] } else { 0.0 };
                min_norm = min_norm.min(norm);
            }
            None => min_norm = min_norm.min(0.0),
        }
    }
    if min_norm == f64::INFINITY {
        min_norm = 0.0;
    }
    (feasible, min_norm)
}

/// Jointly plan `networks` onto one `device`: seeded budget split, per-tenant
/// greedy DSE on budget-clamped views, slack-to-bottleneck rebalancing.
///
/// Returns `None` when no explored budget split yields a feasible design for
/// *every* tenant — including when any tenant is infeasible even solo on the
/// whole device (co-location can only shrink its budget).
pub fn colocate(
    networks: &[Network],
    device: &Device,
    cfg: &DseConfig,
) -> Option<ColocatedResult> {
    if networks.is_empty() {
        return None;
    }

    // Solo baselines: the normalization anchors of the joint objective and
    // the early infeasibility gate. A single tenant IS its solo run — the
    // whole device, untouched (bit-identical to the plain DSE).
    let solo: Vec<Option<DseResult>> =
        parallel_cases(networks, |_, net| run(net, device, cfg));
    let solo_theta: Vec<f64> = solo
        .iter()
        .map(|r| r.as_ref().map(|r| r.throughput).unwrap_or(0.0))
        .collect();
    if solo.iter().any(Option::is_none) {
        return None;
    }
    if networks.len() == 1 {
        let result = solo.into_iter().next().flatten()?;
        let theta = result.throughput;
        return Some(ColocatedResult {
            tenants: vec![TenantPlan {
                name: networks[0].name.clone(),
                share: 1.0,
                view: device.clone(),
                result,
                solo_throughput: theta,
            }],
            min_norm_throughput: 1.0,
            rounds: 0,
        });
    }

    let mut shares = seed_shares(networks);
    let mut memo = std::collections::HashMap::new();
    let mut evals = evaluate(networks, device, &shares, cfg, &mut memo);
    let mut best_score = score(&evals, &solo_theta);
    let mut best: (Vec<f64>, Vec<(Device, Option<DseResult>)>) =
        (shares.clone(), evals.clone());
    let mut round = 0;
    let mut best_round = 0;

    for _ in 0..MAX_ROUNDS {
        // Worst tenant: infeasible first, then lowest normalized throughput.
        let norm = |i: usize| -> f64 {
            match &evals[i].1 {
                None => -1.0,
                Some(r) => {
                    if solo_theta[i] > 0.0 {
                        r.throughput / solo_theta[i]
                    } else {
                        0.0
                    }
                }
            }
        };
        let worst = (0..networks.len())
            .min_by(|&a, &b| norm(a).partial_cmp(&norm(b)).unwrap_or(std::cmp::Ordering::Equal))?;
        // Donor: the most-slack tenant that can still give budget away.
        let donor = (0..networks.len())
            .filter(|&i| i != worst && shares[i] > MIN_SHARE && evals[i].1.is_some())
            .max_by(|&a, &b| norm(a).partial_cmp(&norm(b)).unwrap_or(std::cmp::Ordering::Equal));
        let Some(donor) = donor else { break };
        if norm(donor) <= norm(worst) {
            break; // nobody has slack to spare
        }
        let delta = (shares[donor] * TRANSFER_FRAC).min(shares[donor] - MIN_SHARE);
        if delta <= 1e-4 {
            break;
        }
        shares[donor] -= delta;
        shares[worst] += delta;
        round += 1;

        evals = evaluate(networks, device, &shares, cfg, &mut memo);
        let s = score(&evals, &solo_theta);
        if s > best_score {
            best_score = s;
            best = (shares.clone(), evals.clone());
            best_round = round;
        }
    }

    let (shares, evals) = best;
    if evals.iter().any(|(_, r)| r.is_none()) {
        return None;
    }
    let tenants: Vec<TenantPlan> = evals
        .into_iter()
        .enumerate()
        .map(|(i, (view, result))| TenantPlan {
            name: networks[i].name.clone(),
            share: shares[i],
            view,
            result: result.expect("checked feasible above"),
            solo_throughput: solo_theta[i],
        })
        .collect();
    let min_norm = tenants
        .iter()
        .map(TenantPlan::norm_throughput)
        .fold(f64::INFINITY, f64::min);
    Some(ColocatedResult {
        tenants,
        min_norm_throughput: if min_norm.is_finite() { min_norm } else { 0.0 },
        rounds: best_round,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::Quant;
    use crate::models;

    #[test]
    fn seed_shares_follow_weight_footprint_and_sum_to_one() {
        let nets =
            [models::resnet18(Quant::W4A5), models::squeezenet(Quant::W8A8)];
        let shares = seed_shares(&nets);
        assert_eq!(shares.len(), 2);
        assert!((shares.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        // resnet18 carries far more weight bits than squeezenet
        assert!(shares[0] > shares[1], "{shares:?}");
        for &s in &shares {
            assert!(s >= MIN_SHARE);
        }
    }

    #[test]
    fn seed_floor_survives_extreme_weight_skew() {
        // resnet50 W8A8 outweighs toy_cnn by orders of magnitude; a naive
        // clamp-then-normalize would push toy back below the floor
        let nets = [models::resnet50(Quant::W8A8), models::toy_cnn(Quant::W8A8)];
        let shares = seed_shares(&nets);
        assert!((shares.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        for &s in &shares {
            assert!(s >= MIN_SHARE - 1e-12, "floor must hold: {shares:?}");
        }
        assert!(shares[0] > shares[1]);
        // the pinned tenant sits exactly at the floor
        assert!((shares[1] - MIN_SHARE).abs() < 1e-12, "{shares:?}");
    }

    #[test]
    fn single_tenant_is_the_plain_dse_on_the_whole_device() {
        let net = models::resnet18(Quant::W4A5);
        let dev = Device::zcu102();
        let cfg = DseConfig::default();
        let direct = run(&net, &dev, &cfg).unwrap();
        let joint = colocate(std::slice::from_ref(&net), &dev, &cfg).unwrap();
        assert_eq!(joint.tenants.len(), 1);
        let t = &joint.tenants[0];
        assert_eq!(t.share, 1.0);
        assert_eq!(t.view, dev, "1-tenant view must be the untouched device");
        assert_eq!(t.result.design.cfgs, direct.design.cfgs);
        assert_eq!(t.result.design.off_bits, direct.design.off_bits);
        assert_eq!(t.result.throughput, direct.throughput);
        assert_eq!(joint.min_norm_throughput, 1.0);
    }

    #[test]
    fn two_tenants_fit_jointly_within_the_device() {
        let nets =
            [models::resnet18(Quant::W4A5), models::squeezenet(Quant::W8A8)];
        let dev = Device::zcu102();
        let cfg = DseConfig::default();
        let joint = colocate(&nets, &dev, &cfg).expect("resnet18+squeezenet co-locate on zcu102");
        assert_eq!(joint.tenants.len(), 2);
        assert!((joint.tenants.iter().map(|t| t.share).sum::<f64>() - 1.0).abs() < 1e-9);
        // the joint plan respects every physical cap
        let area = joint.joint_area();
        assert!(area.fits(&dev), "joint area {area:?} must fit {:?}", dev.name);
        assert!(joint.joint_bandwidth_bps() <= dev.bandwidth_bps * (1.0 + 1e-9));
        // every tenant fits its own view too
        for t in &joint.tenants {
            assert!(t.result.area.fits(&t.view), "{}", t.name);
            assert!(t.result.throughput > 0.0);
            // the greedy DSE is not perfectly monotone in budget (see
            // `more_memory_never_hurts`), so a slice may beat solo slightly
            assert!(t.norm_throughput() <= 1.05, "{}", t.norm_throughput());
        }
        assert!(joint.min_norm_throughput > 0.0);
    }

    #[test]
    fn over_budget_tenant_set_is_none_not_a_panic() {
        // Three ResNet50s cannot share a zedboard-sized sliver.
        let nets = [
            models::resnet50(Quant::W8A8),
            models::resnet50(Quant::W8A8),
            models::resnet50(Quant::W8A8),
        ];
        let dev = Device::zedboard();
        assert!(colocate(&nets, &dev, &DseConfig::vanilla()).is_none());
    }

    #[test]
    fn tenant_infeasible_solo_fails_the_joint_search_early() {
        // resnet18 W4A5 does not fit a zedboard without streaming; adding a
        // healthy tenant cannot rescue it
        let nets = [models::resnet18(Quant::W4A5), models::toy_cnn(Quant::W8A8)];
        let dev = Device::zedboard();
        assert!(colocate(&nets, &dev, &DseConfig::vanilla()).is_none());
    }

    #[test]
    fn empty_tenant_list_is_none() {
        assert!(colocate(&[], &Device::zcu102(), &DseConfig::default()).is_none());
    }
}
