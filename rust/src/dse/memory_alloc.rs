//! Greedy memory allocation — Algorithm 1 procedures ALLOCATE_MEMORY,
//! DELTA_BANDWIDTH, WRITE_BURST_BALANCE, INCREMENT_OFFCHIP.
//!
//! §Perf: the eviction loop is incremental. Selection runs on a lazily
//! invalidated min-ΔB binary heap instead of an O(L) rescan per eviction —
//! valid because a layer's ΔB key depends only on its *own* eviction state
//! (cycles are unaffected by eviction and the Eq. 10 repeat target is a
//! network constant), so keys go stale only for the layer just evicted.
//! Generation stamps drop stale entries on pop; the heap pops the same
//! (min ΔB, min index) candidate the linear scan would have picked.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::{Design, DseConfig};
use crate::ce::{self, eval_m_dep, eval_m_wid_bits, Fragmentation};
use crate::device::Device;

/// The common repeat target `r` (Eq. 10): the maximum `b·ĥ·ŵ` over *all*
/// layers of the network (Algorithm 1's `r_max` over `l' ∈ D` with every
/// layer's baseline `n = 1`). Using the global maximum keeps the target
/// stable as the streaming set grows, and gives the finest-output-map layer
/// `n = 1` while coarser layers get proportionally more fragments.
///
/// §Perf: `max_l ĥ_l·ŵ_l` is a network constant cached by
/// [`Design::max_pixels`] — this used to re-reduce over all layers on every
/// burst-balance call, making each eviction's candidate scan O(L²).
pub fn r_target(design: &Design, batch: u64) -> u64 {
    batch * design.max_pixels()
}

/// WRITE_BURST_BALANCE (Algorithm 1, Eq. 10): pick the fragment count `n_l`
/// so that `r_l = b·ĥ_l·ŵ_l·n_l` matches the repeat target. With equal `r`
/// across layers the DMA performs the same number of write bursts per batch
/// for every layer, eliminating the stalls of Fig. 5(a). `n` is capped at
/// the memory depth (cannot have more fragments than words).
pub fn write_burst_balance(design: &Design, l: usize, batch: u64) -> u32 {
    let layer = &design.network.layers[l];
    let pixels = batch * layer.h_out() as u64 * layer.w_out() as u64;
    let n = r_target(design, batch).div_ceil(pixels);
    let m_dep = eval_m_dep(layer, &design.cfgs[l]);
    n.clamp(1, m_dep.max(1)) as u32
}

/// INCREMENT_OFFCHIP: evict one block of depth `μ` (in words of the layer's
/// current memory geometry) from layer `l`, then re-balance burst counts
/// across all streaming layers (Eq. 10). The new off-chip depth is anchored
/// to the *actual* current depth (which may exceed the raw eviction counter
/// due to per-fragment padding) so every call makes strict progress.
pub fn increment_offchip(design: &mut Design, l: usize, cfg: &DseConfig) {
    increment_offchip_by(design, l, cfg, cfg.mu);
}

/// INCREMENT_OFFCHIP with an explicit word count (the bulk phase of
/// ALLOCATE_MEMORY evicts geometrically larger chunks while far over
/// budget, then falls back to `μ`-granularity for the tail).
pub fn increment_offchip_by(design: &mut Design, l: usize, cfg: &DseConfig, words: u64) {
    increment_offchip_tracked(design, l, cfg, words, None);
}

/// [`increment_offchip_by`] that additionally reports which *other* layers
/// had their burst count rebalanced — the eviction heap must re-key those.
fn increment_offchip_tracked(
    design: &mut Design,
    l: usize,
    cfg: &DseConfig,
    words: u64,
    rebalanced: Option<&mut Vec<usize>>,
) {
    design.record_layer(l);
    let m_wid = eval_m_wid_bits(&design.network.layers[l], &design.cfgs[l]);
    let cur = design.cfgs[l].frag.m_off_dep();
    design.off_bits[l] = (cur + words) * m_wid;
    let n = write_burst_balance(design, l, cfg.batch);
    design.set_fragmentation(l, n);
    rebalance_tracked(design, cfg, rebalanced);
}

/// Enforce Eq. 10 across every streaming layer by re-deriving each fragment
/// count from the common repeat target.
pub fn rebalance_all(design: &mut Design, cfg: &DseConfig) {
    rebalance_tracked(design, cfg, None);
}

/// [`rebalance_all`] without the per-eviction `Vec` allocation (§Perf): an
/// index scan over the streaming flags, optionally collecting the layers
/// whose fragment count actually changed.
fn rebalance_tracked(design: &mut Design, cfg: &DseConfig, mut changed: Option<&mut Vec<usize>>) {
    for i in 0..design.len() {
        if !design.cfgs[i].frag.is_streaming() {
            continue;
        }
        let n = write_burst_balance(design, i, cfg.batch);
        if n != design.cfgs[i].frag.n {
            design.set_fragmentation(i, n);
            if let Some(out) = changed.as_deref_mut() {
                out.push(i);
            }
        }
    }
}

/// DELTA_BANDWIDTH: total-bandwidth increase if layer `l` were evicted one
/// more `μ`-block. Closed form — eviction changes neither θ nor `β_io`, so
///
/// ```text
/// ΔB = s_l · M_wid_l · clk_comp · Δ(off-chip ratio)
/// ```
///
/// This is the greedy selection criterion, visualized as the red curve of
/// paper Fig. 7.
pub fn delta_bandwidth(design: &Design, l: usize, cfg: &DseConfig) -> f64 {
    delta_bandwidth_by(design, l, cfg, cfg.mu)
}

/// DELTA_BANDWIDTH for an explicit eviction word count.
pub fn delta_bandwidth_by(design: &Design, l: usize, cfg: &DseConfig, words: u64) -> f64 {
    let layer = &design.network.layers[l];
    let m_dep = eval_m_dep(layer, &design.cfgs[l]);
    let m_wid = eval_m_wid_bits(layer, &design.cfgs[l]);
    if m_dep == 0 || m_wid == 0 {
        return f64::INFINITY; // no weights memory: nothing to evict
    }
    let old_off = design.cfgs[l].frag.m_off_dep().min(m_dep);
    // The eviction is quantized by the balanced fragment count: the new
    // off-chip depth is u_off'·n, matching what INCREMENT_OFFCHIP will do.
    let n = write_burst_balance(design, l, cfg.batch) as u64;
    let requested = (old_off + words).min(m_dep);
    let u = m_dep.div_ceil(n);
    let u_off = requested.div_ceil(n).min(u);
    let new_off = (u_off * n).min(m_dep);
    let d_ratio = (new_off as f64 - old_off as f64) / m_dep as f64;
    bandwidth_delta(design.slowdown(l), m_wid, design.clk_comp_mhz, d_ratio)
}

/// The Eq. 5 closed form shared by eviction (forward ΔB) and the warm-start
/// un-evict ranking (reverse ΔB): `s_l · M_wid · clk_comp · Δratio`.
fn bandwidth_delta(slowdown: f64, m_wid_bits: u64, clk_comp_mhz: f64, d_ratio: f64) -> f64 {
    slowdown * m_wid_bits as f64 * clk_comp_mhz * 1e6 * d_ratio
}

/// Min-heap entry for the greedy eviction candidate set: orders by
/// (ΔB ascending, layer index ascending) so the pop order is identical to
/// the linear scan's first-minimal-index selection. `gen` invalidates
/// entries lazily: when a layer is evicted (or rebalanced) its generation
/// advances and a fresh entry is pushed; stale entries are dropped on pop.
struct MinDeltaB {
    key: f64,
    layer: usize,
    gen: u32,
}

impl PartialEq for MinDeltaB {
    fn eq(&self, o: &Self) -> bool {
        self.cmp(o) == Ordering::Equal
    }
}
impl Eq for MinDeltaB {}
impl PartialOrd for MinDeltaB {
    fn partial_cmp(&self, o: &Self) -> Option<Ordering> {
        Some(self.cmp(o))
    }
}
impl Ord for MinDeltaB {
    fn cmp(&self, o: &Self) -> Ordering {
        // reversed: BinaryHeap is a max-heap, we want min ΔB (then min index)
        o.key.total_cmp(&self.key).then_with(|| o.layer.cmp(&self.layer))
    }
}

/// Is layer `i` an eviction candidate (weight layer with words still
/// on-chip)?
fn evictable(design: &Design, i: usize) -> bool {
    design.network.layers[i].has_weights() && design.cfgs[i].frag.m_on_dep() > 0
}

/// The eviction core shared by the cold and warm allocation paths: evict
/// min-ΔB blocks until on-chip memory fits the budget. Returns `false` when
/// the bandwidth constraint would be violated or everything evictable is
/// already off-chip.
fn evict_until_fit(design: &mut Design, device: &Device, cfg: &DseConfig) -> bool {
    let budget = device.mem_bram_equiv();
    if design.mem_blocks() <= budget {
        return true;
    }
    if !cfg.allow_streaming {
        return false; // vanilla: weights must fit on-chip
    }

    // Lazily invalidated min-ΔB heap over the candidate layers (§Perf:
    // replaces the per-eviction O(L) rescan — and the O(L) `r_target`
    // reduction it ran per candidate).
    let mut gen = vec![0u32; design.len()];
    let mut heap: BinaryHeap<MinDeltaB> = BinaryHeap::with_capacity(design.len());
    for i in 0..design.len() {
        if evictable(design, i) {
            heap.push(MinDeltaB { key: delta_bandwidth(design, i, cfg), layer: i, gen: 0 });
        }
    }

    let mut rebalanced: Vec<usize> = Vec::new();
    while design.mem_blocks() > budget {
        // pop the freshest minimal-ΔB candidate; stale generations drop out
        let l = loop {
            let popped = heap.pop();
            if popped.is_some() {
                // stale pops included: the lazy-invalidation overhead is
                // part of the telemetry signal
                crate::telemetry::counters().dse_heap_pops.incr();
            }
            match popped {
                None => return false, // everything already evicted and still over budget
                Some(e) if e.gen == gen[e.layer] => break e.layer,
                Some(_) => continue,
            }
        };
        // Adaptive quantum: aim to close ~1/4 of the deficit through this
        // layer, but never less than μ.
        let deficit_blocks = design.mem_blocks().saturating_sub(budget) as u64;
        let m_wid = eval_m_wid_bits(&design.network.layers[l], &design.cfgs[l]).max(1);
        let words =
            cfg.mu.max(deficit_blocks * crate::device::BRAM36_BITS / (4 * m_wid));
        let db = delta_bandwidth_by(design, l, cfg, words);
        if design.total_bandwidth() + db > device.bandwidth_bps * cfg.bw_margin {
            return false; // bandwidth limit (Algorithm 1)
        }
        rebalanced.clear();
        increment_offchip_tracked(design, l, cfg, words, Some(&mut rebalanced));
        // re-key the evicted layer (its ΔB moved)
        gen[l] = gen[l].wrapping_add(1);
        if evictable(design, l) {
            heap.push(MinDeltaB { key: delta_bandwidth(design, l, cfg), layer: l, gen: gen[l] });
        }
        // Burst rebalancing cannot change other layers mid-loop (the Eq. 10
        // target is geometry-derived, and geometry is fixed here), but if it
        // ever does, re-key those layers too rather than diverge.
        for idx in 0..rebalanced.len() {
            let j = rebalanced[idx];
            if j == l {
                continue;
            }
            gen[j] = gen[j].wrapping_add(1);
            if evictable(design, j) {
                heap.push(MinDeltaB {
                    key: delta_bandwidth(design, j, cfg),
                    layer: j,
                    gen: gen[j],
                });
            }
        }
    }
    true
}

/// ALLOCATE_MEMORY: starting from the all-on-chip state (Algorithm 1
/// INITIALIZE sets `M_off = 0`; each run re-derives the eviction set for the
/// *current* unroll geometry), evict blocks — layer chosen by minimal ΔB —
/// until on-chip memory fits the device budget. Returns `false` when the
/// bandwidth constraint would be violated (the caller then stops allocating
/// compute) or when streaming is disabled and memory does not fit (the
/// vanilla baseline's infeasibility).
///
/// While far over budget, the eviction quantum grows geometrically (the
/// greedy ΔB ordering is still applied per chunk); the final approach to the
/// budget uses the fine `μ` granularity of the paper.
pub fn allocate_memory(design: &mut Design, device: &Device, cfg: &DseConfig) -> bool {
    // Fresh start: all weights back on-chip for the current geometry.
    for i in 0..design.len() {
        if design.off_bits[i] != 0 || design.cfgs[i].frag.is_streaming() {
            design.record_layer(i);
            design.off_bits[i] = 0;
            design.set_fragmentation(i, 1);
        }
    }
    evict_until_fit(design, device, cfg)
}

/// Warm-start ALLOCATE_MEMORY (§Perf): instead of resetting every layer to
/// on-chip and re-deriving the whole eviction set after a single-layer
/// unroll, keep the previous eviction state (the evicted *bits* are the
/// geometry-independent invariant) and repair it incrementally:
///
/// - over budget  → continue greedy min-ΔB eviction from where we are;
/// - under budget → greedily *un-evict*, pulling back the `μ`-block with the
///   largest ΔB (the mirror image of the eviction criterion, i.e. the
///   marginal Fig. 7 logic run in reverse) while the result still fits.
///
/// When the design never streams, this is step-for-step identical to the
/// cold path (the reset is vacuous and both run the same eviction core), so
/// compute-bound workloads get bit-identical designs. On eviction-heavy
/// workloads the repaired eviction set is a greedy approximation of the
/// re-derived one: same budget and bandwidth guarantees, but chunk-rounding
/// may differ — which is why it is opt-in via [`DseConfig::warm_start`] and
/// cross-checked against the cold path in `tests/dse_equivalence.rs`.
pub fn allocate_memory_warm(design: &mut Design, device: &Device, cfg: &DseConfig) -> bool {
    if !cfg.allow_streaming {
        // vanilla has no eviction state to warm-start
        return allocate_memory(design, device, cfg);
    }
    let budget = device.mem_bram_equiv();
    if design.mem_blocks() > budget {
        return evict_until_fit(design, device, cfg);
    }
    // Under budget: drain evictions while they fit back on-chip.
    loop {
        let Some((l, new_off_words)) = best_unevict_candidate(design, cfg) else { break };
        let m_wid = eval_m_wid_bits(&design.network.layers[l], &design.cfgs[l]).max(1);
        // predict the memory effect without mutating (no nested trial logs)
        let predicted = predict_blocks_at(design, l, new_off_words * m_wid, cfg);
        let after = design.mem_blocks() - design.area_of(l).bram.total() + predicted;
        if after > budget {
            break; // pulling this block back would overflow on-chip memory
        }
        let before_off = design.cfgs[l].frag.m_off_dep();
        design.record_layer(l);
        design.off_bits[l] = new_off_words * m_wid;
        let n = if new_off_words == 0 { 1 } else { write_burst_balance(design, l, cfg.batch) };
        design.set_fragmentation(l, n);
        rebalance_tracked(design, cfg, None);
        if design.cfgs[l].frag.m_off_dep() >= before_off {
            // fragment re-padding swallowed the pull-back (cannot happen
            // while unrolls only grow, where n never increases); stop rather
            // than spin
            break;
        }
    }
    true
}

/// Un-eviction target for layer `i`: pull back at least `μ` words, in whole
/// rows of the fragment grid (`n` words per row) so the re-derived
/// fragmentation shrinks strictly and the drain loop terminates even when
/// `μ < n`. Returns the new off-chip word count.
fn unevict_target(design: &Design, i: usize, cfg: &DseConfig) -> u64 {
    let n = design.cfgs[i].frag.n.max(1) as u64;
    let u_off = design.cfgs[i].frag.u_off;
    let rows = cfg.mu.div_ceil(n).max(1);
    u_off.saturating_sub(rows) * n
}

/// The streaming layer whose trailing eviction rows cost the most bandwidth
/// — the first to pull back on-chip when memory frees up — together with
/// its un-eviction target.
fn best_unevict_candidate(design: &Design, cfg: &DseConfig) -> Option<(usize, u64)> {
    let mut best: Option<(usize, u64, f64)> = None;
    for i in design.streaming_layer_iter() {
        let layer = &design.network.layers[i];
        let m_dep = eval_m_dep(layer, &design.cfgs[i]);
        let m_wid = eval_m_wid_bits(layer, &design.cfgs[i]);
        if m_dep == 0 || m_wid == 0 {
            continue;
        }
        let old_off = design.cfgs[i].frag.m_off_dep().min(m_dep);
        let new_off = unevict_target(design, i, cfg).min(old_off);
        let d_ratio = (old_off - new_off) as f64 / m_dep as f64;
        let saved = bandwidth_delta(design.slowdown(i), m_wid, design.clk_comp_mhz, d_ratio);
        if best.is_none_or(|(_, _, b)| saved > b) {
            best = Some((i, new_off, saved));
        }
    }
    best.map(|(i, new_off, _)| (i, new_off))
}

/// BRAM blocks layer `l` would occupy with its evicted bits set to
/// `off_bits_new` (pure prediction — mirrors [`Design::set_fragmentation`]
/// without mutating).
fn predict_blocks_at(design: &Design, l: usize, off_bits_new: u64, cfg: &DseConfig) -> u32 {
    let layer = &design.network.layers[l];
    let cfg_l = &design.cfgs[l];
    let m_dep = eval_m_dep(layer, cfg_l);
    let m_wid = eval_m_wid_bits(layer, cfg_l);
    let m_off = if m_wid == 0 { 0 } else { off_bits_new.div_ceil(m_wid).min(m_dep) };
    let mut probe = *cfg_l;
    probe.frag = if m_off == 0 {
        Fragmentation::all_on_chip(m_dep)
    } else {
        let n = write_burst_balance(design, l, cfg.batch).max(1);
        Fragmentation::new(m_dep, m_off, n)
    };
    ce::eval_area(layer, &probe).bram.total()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::DseConfig;
    use crate::ir::Quant;
    use crate::models;

    fn setup() -> (Design, Device, DseConfig) {
        let net = models::resnet18(Quant::W4A5);
        let dev = Device::zcu102();
        (Design::initialize(&net, &dev), dev, DseConfig::default())
    }

    #[test]
    fn first_streaming_layer_gets_n_1() {
        let (d, _, cfg) = setup();
        let wl = d.network.weight_layers();
        assert_eq!(write_burst_balance(&d, wl[0], cfg.batch), 1);
    }

    #[test]
    fn r_target_matches_fresh_reduction() {
        let (d, _, _) = setup();
        for batch in [1u64, 4, 16] {
            let fresh = d
                .network
                .layers
                .iter()
                .map(|l| batch * l.h_out() as u64 * l.w_out() as u64)
                .max()
                .unwrap_or(1);
            assert_eq!(r_target(&d, batch), fresh);
        }
    }

    #[test]
    fn burst_balance_equalizes_r() {
        let (mut d, _, cfg) = setup();
        // evict from two layers with very different output maps
        let wl = d.network.weight_layers();
        let early = wl[1]; // large feature map
        let late = *wl.last().unwrap(); // fc: 1x1 map
        increment_offchip(&mut d, early, &cfg);
        increment_offchip(&mut d, late, &cfg);
        let r_early = d.repeats(early, cfg.batch);
        let r_late = d.repeats(late, cfg.batch);
        let ratio = r_early.max(r_late) as f64 / r_early.min(r_late) as f64;
        assert!(ratio < 1.05, "r {} vs {} not balanced", r_early, r_late);
    }

    #[test]
    fn eviction_increases_bandwidth_monotonically() {
        let (mut d, _, cfg) = setup();
        let l = d.network.weight_layers()[3];
        let mut last = d.total_bandwidth();
        for _ in 0..5 {
            increment_offchip(&mut d, l, &cfg);
            let bw = d.total_bandwidth();
            assert!(bw >= last - 1e-6);
            last = bw;
        }
    }

    #[test]
    fn closed_form_delta_matches_measured() {
        let (d, _, cfg) = setup();
        for &i in &d.network.weight_layers()[..6] {
            let predicted = delta_bandwidth(&d, i, &cfg);
            let mut trial = d.clone();
            let before = trial.total_bandwidth();
            increment_offchip(&mut trial, i, &cfg);
            let measured = trial.total_bandwidth() - before;
            let denom = measured.abs().max(1.0);
            assert!(
                (predicted - measured).abs() / denom < 0.05,
                "layer {i}: predicted {predicted} vs measured {measured}"
            );
        }
    }

    #[test]
    fn allocate_memory_reaches_budget() {
        let (mut d, dev, cfg) = setup();
        assert!(
            d.mem_blocks() > dev.mem_bram_equiv(),
            "serial resnet18-W4 should initially exceed zcu102: {} vs {}",
            d.mem_blocks(),
            dev.mem_bram_equiv()
        );
        assert!(allocate_memory(&mut d, &dev, &cfg));
        assert!(d.mem_blocks() <= dev.mem_bram_equiv());
        assert!(d.any_streaming());
        d.assert_aggregates_consistent();
    }

    #[test]
    fn vanilla_fails_when_over_budget() {
        let net = models::resnet18(Quant::W4A5);
        let dev = Device::zedboard();
        let mut d = Design::initialize(&net, &dev);
        let cfg = DseConfig::vanilla();
        assert!(!allocate_memory(&mut d, &dev, &cfg));
    }

    #[test]
    fn streaming_layers_after_allocation_follow_min_delta_b() {
        // The evicted set should favor layers with small ΔB: verify the
        // maximum ΔB among evicted layers does not exceed the minimum ΔB
        // among retained layers by more than a small factor (greedy order).
        let (mut d, dev, cfg) = setup();
        allocate_memory(&mut d, &dev, &cfg);
        let evicted: Vec<usize> = d.streaming_layers();
        assert!(!evicted.is_empty());
        // every evicted layer has weights
        assert!(evicted.iter().all(|&i| d.network.layers[i].has_weights()));
    }

    #[test]
    fn warm_allocation_from_scratch_matches_cold() {
        // With no prior eviction state the warm path must run the exact same
        // eviction core as the cold path.
        let (d, dev, cfg) = setup();
        let mut cold = d.clone();
        let mut warm = d.clone();
        assert!(allocate_memory(&mut cold, &dev, &cfg));
        assert!(allocate_memory_warm(&mut warm, &dev, &cfg));
        assert_eq!(cold.off_bits, warm.off_bits);
        assert_eq!(cold.cfgs, warm.cfgs);
        assert!(cold.total_bandwidth() == warm.total_bandwidth());
    }

    #[test]
    fn warm_allocation_drains_when_memory_frees_up() {
        // Evict on a tight device, then hand the design a huge budget: the
        // warm path must pull the weights back on-chip.
        let (mut d, dev, cfg) = setup();
        assert!(allocate_memory(&mut d, &dev, &cfg));
        assert!(d.any_streaming());
        let big = dev.with_mem_scale(20.0);
        assert!(allocate_memory_warm(&mut d, &big, &cfg));
        assert!(!d.any_streaming(), "ample memory must drain the eviction set");
        assert_eq!(d.off_bits.iter().filter(|&&b| b != 0).count(), 0);
        d.assert_aggregates_consistent();
    }

    #[test]
    fn warm_allocation_stays_feasible_on_tight_budget() {
        let (mut d, dev, cfg) = setup();
        assert!(allocate_memory(&mut d, &dev, &cfg));
        // shrink memory further: warm path must evict more, not reset
        let tight = dev.with_mem_scale(0.8);
        assert!(allocate_memory_warm(&mut d, &tight, &cfg));
        assert!(d.mem_blocks() <= tight.mem_bram_equiv());
        d.assert_aggregates_consistent();
    }
}
