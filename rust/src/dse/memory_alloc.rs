//! Greedy memory allocation — Algorithm 1 procedures ALLOCATE_MEMORY,
//! DELTA_BANDWIDTH, WRITE_BURST_BALANCE, INCREMENT_OFFCHIP.

use super::{Design, DseConfig};
use crate::ce::{eval_m_dep, eval_m_wid_bits};
use crate::device::Device;

/// The common repeat target `r` (Eq. 10): the maximum `b·ĥ·ŵ` over *all*
/// layers of the network (Algorithm 1's `r_max` over `l' ∈ D` with every
/// layer's baseline `n = 1`). Using the global maximum keeps the target
/// stable as the streaming set grows, and gives the finest-output-map layer
/// `n = 1` while coarser layers get proportionally more fragments.
pub fn r_target(design: &Design, batch: u64) -> u64 {
    design
        .network
        .layers
        .iter()
        .map(|l| batch * l.h_out() as u64 * l.w_out() as u64)
        .max()
        .unwrap_or(1)
}

/// WRITE_BURST_BALANCE (Algorithm 1, Eq. 10): pick the fragment count `n_l`
/// so that `r_l = b·ĥ_l·ŵ_l·n_l` matches the repeat target. With equal `r`
/// across layers the DMA performs the same number of write bursts per batch
/// for every layer, eliminating the stalls of Fig. 5(a). `n` is capped at
/// the memory depth (cannot have more fragments than words).
pub fn write_burst_balance(design: &Design, l: usize, batch: u64) -> u32 {
    let layer = &design.network.layers[l];
    let pixels = batch * layer.h_out() as u64 * layer.w_out() as u64;
    let n = r_target(design, batch).div_ceil(pixels);
    let m_dep = eval_m_dep(layer, &design.cfgs[l]);
    n.clamp(1, m_dep.max(1)) as u32
}

/// INCREMENT_OFFCHIP: evict one block of depth `μ` (in words of the layer's
/// current memory geometry) from layer `l`, then re-balance burst counts
/// across all streaming layers (Eq. 10). The new off-chip depth is anchored
/// to the *actual* current depth (which may exceed the raw eviction counter
/// due to per-fragment padding) so every call makes strict progress.
pub fn increment_offchip(design: &mut Design, l: usize, cfg: &DseConfig) {
    increment_offchip_by(design, l, cfg, cfg.mu);
}

/// INCREMENT_OFFCHIP with an explicit word count (the bulk phase of
/// ALLOCATE_MEMORY evicts geometrically larger chunks while far over
/// budget, then falls back to `μ`-granularity for the tail).
pub fn increment_offchip_by(design: &mut Design, l: usize, cfg: &DseConfig, words: u64) {
    let m_wid = eval_m_wid_bits(&design.network.layers[l], &design.cfgs[l]);
    let cur = design.cfgs[l].frag.m_off_dep();
    design.off_bits[l] = (cur + words) * m_wid;
    let n = write_burst_balance(design, l, cfg.batch);
    design.set_fragmentation(l, n);
    rebalance_all(design, cfg);
}

/// Enforce Eq. 10 across every streaming layer by re-deriving each fragment
/// count from the common repeat target.
pub fn rebalance_all(design: &mut Design, cfg: &DseConfig) {
    for i in design.streaming_layers() {
        let n = write_burst_balance(design, i, cfg.batch);
        if n != design.cfgs[i].frag.n {
            design.set_fragmentation(i, n);
        }
    }
}

/// DELTA_BANDWIDTH: total-bandwidth increase if layer `l` were evicted one
/// more `μ`-block. Closed form — eviction changes neither θ nor `β_io`, so
///
/// ```text
/// ΔB = s_l · M_wid_l · clk_comp · Δ(off-chip ratio)
/// ```
///
/// This is the greedy selection criterion, visualized as the red curve of
/// paper Fig. 7.
pub fn delta_bandwidth(design: &Design, l: usize, cfg: &DseConfig) -> f64 {
    delta_bandwidth_by(design, l, cfg, cfg.mu)
}

/// DELTA_BANDWIDTH for an explicit eviction word count.
pub fn delta_bandwidth_by(design: &Design, l: usize, cfg: &DseConfig, words: u64) -> f64 {
    let layer = &design.network.layers[l];
    let m_dep = eval_m_dep(layer, &design.cfgs[l]);
    let m_wid = eval_m_wid_bits(layer, &design.cfgs[l]);
    if m_dep == 0 || m_wid == 0 {
        return f64::INFINITY; // no weights memory: nothing to evict
    }
    let old_off = design.cfgs[l].frag.m_off_dep().min(m_dep);
    // The eviction is quantized by the balanced fragment count: the new
    // off-chip depth is u_off'·n, matching what INCREMENT_OFFCHIP will do.
    let n = write_burst_balance(design, l, cfg.batch) as u64;
    let requested = (old_off + words).min(m_dep);
    let u = m_dep.div_ceil(n);
    let u_off = requested.div_ceil(n).min(u);
    let new_off = (u_off * n).min(m_dep);
    let d_ratio = (new_off as f64 - old_off as f64) / m_dep as f64;
    design.slowdown(l) * m_wid as f64 * design.clk_comp_mhz * 1e6 * d_ratio
}

/// ALLOCATE_MEMORY: starting from the all-on-chip state (Algorithm 1
/// INITIALIZE sets `M_off = 0`; each run re-derives the eviction set for the
/// *current* unroll geometry), evict blocks — layer chosen by minimal ΔB —
/// until on-chip memory fits the device budget. Returns `false` when the
/// bandwidth constraint would be violated (the caller then stops allocating
/// compute) or when streaming is disabled and memory does not fit (the
/// vanilla baseline's infeasibility).
///
/// While far over budget, the eviction quantum grows geometrically (the
/// greedy ΔB ordering is still applied per chunk); the final approach to the
/// budget uses the fine `μ` granularity of the paper.
pub fn allocate_memory(design: &mut Design, device: &Device, cfg: &DseConfig) -> bool {
    let budget = device.mem_bram_equiv();
    // Fresh start: all weights back on-chip for the current geometry.
    for i in 0..design.len() {
        if design.off_bits[i] != 0 || design.cfgs[i].frag.is_streaming() {
            design.off_bits[i] = 0;
            design.set_fragmentation(i, 1);
        }
    }
    while design.mem_blocks() > budget {
        if !cfg.allow_streaming {
            return false; // vanilla: weights must fit on-chip
        }
        // candidate layers: weight layers with something left on-chip
        let mut best: Option<(usize, f64)> = None;
        for i in 0..design.len() {
            if !design.network.layers[i].has_weights()
                || design.cfgs[i].frag.m_on_dep() == 0
            {
                continue;
            }
            let db = delta_bandwidth(design, i, cfg);
            if best.is_none_or(|(_, b)| db < b) {
                best = Some((i, db));
            }
        }
        let Some((l, _)) = best else {
            return false; // everything already evicted and still over budget
        };
        // Adaptive quantum: aim to close ~1/4 of the deficit through this
        // layer, but never less than μ.
        let deficit_blocks = design.mem_blocks().saturating_sub(budget) as u64;
        let m_wid = eval_m_wid_bits(&design.network.layers[l], &design.cfgs[l]).max(1);
        let words =
            cfg.mu.max(deficit_blocks * crate::device::BRAM36_BITS / (4 * m_wid));
        let db = delta_bandwidth_by(design, l, cfg, words);
        if design.total_bandwidth() + db > device.bandwidth_bps * cfg.bw_margin {
            return false; // bandwidth limit (Algorithm 1)
        }
        increment_offchip_by(design, l, cfg, words);
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::DseConfig;
    use crate::ir::Quant;
    use crate::models;

    fn setup() -> (Design, Device, DseConfig) {
        let net = models::resnet18(Quant::W4A5);
        let dev = Device::zcu102();
        (Design::initialize(&net, &dev), dev, DseConfig::default())
    }

    #[test]
    fn first_streaming_layer_gets_n_1() {
        let (d, _, cfg) = setup();
        let wl = d.network.weight_layers();
        assert_eq!(write_burst_balance(&d, wl[0], cfg.batch), 1);
    }

    #[test]
    fn burst_balance_equalizes_r() {
        let (mut d, _, cfg) = setup();
        // evict from two layers with very different output maps
        let wl = d.network.weight_layers();
        let early = wl[1]; // large feature map
        let late = *wl.last().unwrap(); // fc: 1x1 map
        increment_offchip(&mut d, early, &cfg);
        increment_offchip(&mut d, late, &cfg);
        let r_early = d.repeats(early, cfg.batch);
        let r_late = d.repeats(late, cfg.batch);
        let ratio = r_early.max(r_late) as f64 / r_early.min(r_late) as f64;
        assert!(ratio < 1.05, "r {} vs {} not balanced", r_early, r_late);
    }

    #[test]
    fn eviction_increases_bandwidth_monotonically() {
        let (mut d, _, cfg) = setup();
        let l = d.network.weight_layers()[3];
        let mut last = d.total_bandwidth();
        for _ in 0..5 {
            increment_offchip(&mut d, l, &cfg);
            let bw = d.total_bandwidth();
            assert!(bw >= last - 1e-6);
            last = bw;
        }
    }

    #[test]
    fn closed_form_delta_matches_measured() {
        let (d, _, cfg) = setup();
        for &i in &d.network.weight_layers()[..6] {
            let predicted = delta_bandwidth(&d, i, &cfg);
            let mut trial = d.clone();
            let before = trial.total_bandwidth();
            increment_offchip(&mut trial, i, &cfg);
            let measured = trial.total_bandwidth() - before;
            let denom = measured.abs().max(1.0);
            assert!(
                (predicted - measured).abs() / denom < 0.05,
                "layer {i}: predicted {predicted} vs measured {measured}"
            );
        }
    }

    #[test]
    fn allocate_memory_reaches_budget() {
        let (mut d, dev, cfg) = setup();
        assert!(
            d.mem_blocks() > dev.mem_bram_equiv(),
            "serial resnet18-W4 should initially exceed zcu102: {} vs {}",
            d.mem_blocks(),
            dev.mem_bram_equiv()
        );
        assert!(allocate_memory(&mut d, &dev, &cfg));
        assert!(d.mem_blocks() <= dev.mem_bram_equiv());
        assert!(d.any_streaming());
    }

    #[test]
    fn vanilla_fails_when_over_budget() {
        let net = models::resnet18(Quant::W4A5);
        let dev = Device::zedboard();
        let mut d = Design::initialize(&net, &dev);
        let cfg = DseConfig::vanilla();
        assert!(!allocate_memory(&mut d, &dev, &cfg));
    }

    #[test]
    fn streaming_layers_after_allocation_follow_min_delta_b() {
        // The evicted set should favor layers with small ΔB: verify the
        // maximum ΔB among evicted layers does not exceed the minimum ΔB
        // among retained layers by more than a small factor (greedy order).
        let (mut d, dev, cfg) = setup();
        allocate_memory(&mut d, &dev, &cfg);
        let evicted: Vec<usize> = d.streaming_layers();
        assert!(!evicted.is_empty());
        // every evicted layer has weights
        assert!(evicted.iter().all(|&i| d.network.layers[i].has_weights()));
    }
}
