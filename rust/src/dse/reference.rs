//! Pre-refactor reference engine — the equivalence oracle for the
//! incremental DSE.
//!
//! This module preserves the recompute-from-scratch shape of the original
//! Algorithm 1 implementation:
//!
//! - eviction candidates selected by a linear O(L) min-ΔB rescan per
//!   eviction, with the Eq. 10 repeat target re-reduced over all layers per
//!   candidate (the O(L²) term the heap removed);
//! - one full `Design` clone per compute-allocation trial instead of an
//!   undo-log trial.
//!
//! Feasibility thresholds intentionally read the same `Design` aggregate
//! queries as the incremental engine (identical floating-point expressions),
//! so both engines make bit-identical decisions and
//! `tests/dse_equivalence.rs` can assert exact equality of the resulting
//! designs. `benches/dse_perf.rs --compare` times this module as the
//! "before" column of `BENCH_dse.json`.

use super::{delta_bandwidth_by, increment_offchip_by, increment_unroll, Design, DseConfig,
            DseResult};
use crate::ce::{eval_m_dep, eval_m_wid_bits};
use crate::device::Device;
use crate::ir::Network;

/// The Eq. 10 repeat target computed the pre-refactor way: a fresh reduction
/// over every layer. Bit-identical to [`super::r_target`] (both are exact
/// integer maxima); this one just pays O(L) per call.
pub fn r_target_scan(design: &Design, batch: u64) -> u64 {
    design
        .network
        .layers
        .iter()
        .map(|l| batch * l.h_out() as u64 * l.w_out() as u64)
        .max()
        .unwrap_or(1)
}

/// WRITE_BURST_BALANCE with the O(L) repeat-target reduction.
fn write_burst_balance_scan(design: &Design, l: usize, batch: u64) -> u32 {
    let layer = &design.network.layers[l];
    let pixels = batch * layer.h_out() as u64 * layer.w_out() as u64;
    let n = r_target_scan(design, batch).div_ceil(pixels);
    let m_dep = eval_m_dep(layer, &design.cfgs[l]);
    n.clamp(1, m_dep.max(1)) as u32
}

/// DELTA_BANDWIDTH with the scan-based burst balance. Same closed form and
/// same inputs as [`super::delta_bandwidth`], hence bit-identical values.
fn delta_bandwidth_scan(design: &Design, l: usize, cfg: &DseConfig) -> f64 {
    let layer = &design.network.layers[l];
    let m_dep = eval_m_dep(layer, &design.cfgs[l]);
    let m_wid = eval_m_wid_bits(layer, &design.cfgs[l]);
    if m_dep == 0 || m_wid == 0 {
        return f64::INFINITY;
    }
    let old_off = design.cfgs[l].frag.m_off_dep().min(m_dep);
    let n = write_burst_balance_scan(design, l, cfg.batch) as u64;
    let requested = (old_off + cfg.mu).min(m_dep);
    let u = m_dep.div_ceil(n);
    let u_off = requested.div_ceil(n).min(u);
    let new_off = (u_off * n).min(m_dep);
    let d_ratio = (new_off as f64 - old_off as f64) / m_dep as f64;
    design.slowdown(l) * m_wid as f64 * design.clk_comp_mhz * 1e6 * d_ratio
}

/// ALLOCATE_MEMORY, pre-refactor shape: full reset to on-chip, then a linear
/// min-ΔB rescan per eviction.
pub fn allocate_memory(design: &mut Design, device: &Device, cfg: &DseConfig) -> bool {
    let budget = device.mem_bram_equiv();
    // Fresh start: all weights back on-chip for the current geometry.
    for i in 0..design.len() {
        if design.off_bits[i] != 0 || design.cfgs[i].frag.is_streaming() {
            design.record_layer(i);
            design.off_bits[i] = 0;
            design.set_fragmentation(i, 1);
        }
    }
    while design.mem_blocks() > budget {
        if !cfg.allow_streaming {
            return false; // vanilla: weights must fit on-chip
        }
        // candidate layers: weight layers with something left on-chip
        let mut best: Option<(usize, f64)> = None;
        for i in 0..design.len() {
            if !design.network.layers[i].has_weights()
                || design.cfgs[i].frag.m_on_dep() == 0
            {
                continue;
            }
            let db = delta_bandwidth_scan(design, i, cfg);
            if best.is_none_or(|(_, b)| db < b) {
                best = Some((i, db));
            }
        }
        let Some((l, _)) = best else {
            return false; // everything already evicted and still over budget
        };
        // Adaptive quantum: aim to close ~1/4 of the deficit through this
        // layer, but never less than μ.
        let deficit_blocks = design.mem_blocks().saturating_sub(budget) as u64;
        let m_wid = eval_m_wid_bits(&design.network.layers[l], &design.cfgs[l]).max(1);
        let words =
            cfg.mu.max(deficit_blocks * crate::device::BRAM36_BITS / (4 * m_wid));
        let db = delta_bandwidth_by(design, l, cfg, words);
        if design.total_bandwidth() + db > device.bandwidth_bps * cfg.bw_margin {
            return false; // bandwidth limit (Algorithm 1)
        }
        increment_offchip_by(design, l, cfg, words);
    }
    true
}

/// ALLOCATE_COMPUTE, pre-refactor shape: one full `Design` clone per trial.
pub fn allocate_compute(design: &mut Design, device: &Device, cfg: &DseConfig) -> usize {
    let mut accepted = 0;
    loop {
        let l = design.slowest();
        let mut trial = design.clone();
        if !increment_unroll(&mut trial, l, cfg.phi) {
            break; // bottleneck CE saturated
        }
        let fitted = allocate_memory(&mut trial, device, cfg);
        if !fitted
            || !trial.total_area().fits(device)
            || trial.total_bandwidth() > device.bandwidth_bps * cfg.bw_margin
        {
            break; // area or bandwidth limit reached
        }
        *design = trial;
        accepted += 1;
    }
    accepted
}

/// Algorithm 1 end-to-end with the pre-refactor engine.
pub fn run(network: &Network, device: &Device, cfg: &DseConfig) -> Option<DseResult> {
    let mut design = Design::initialize(network, device);
    if !allocate_memory(&mut design, device, cfg) {
        return None;
    }
    if !design.total_area().fits(device) {
        return None;
    }
    let iterations = allocate_compute(&mut design, device, cfg);
    let throughput = design.min_throughput();
    Some(DseResult {
        throughput,
        latency_ms: design.latency_ms(1),
        area: design.total_area(),
        bandwidth_bps: design.total_bandwidth(),
        iterations,
        design,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse;
    use crate::ir::Quant;
    use crate::models;

    #[test]
    fn scan_delta_matches_heap_key() {
        let net = models::resnet18(Quant::W4A5);
        let dev = Device::zcu102();
        let d = Design::initialize(&net, &dev);
        let cfg = DseConfig::default();
        for &i in &net.weight_layers() {
            let scan = delta_bandwidth_scan(&d, i, &cfg);
            let fast = dse::delta_bandwidth(&d, i, &cfg);
            assert!(scan == fast, "layer {i}: scan {scan} vs incremental {fast}");
        }
    }

    #[test]
    fn reference_engine_is_feasible_end_to_end() {
        let net = models::toy_cnn(Quant::W8A8);
        let dev = Device::zcu102();
        let r = run(&net, &dev, &DseConfig::default()).expect("feasible");
        assert!(r.area.fits(&dev));
        assert!(r.throughput > 0.0);
    }
}
