//! Parameter-sweep harness for paper Fig. 6: vary the on-chip memory budget
//! `A_mem` while keeping compute (LUT/DSP) and off-chip bandwidth fixed, and
//! record AutoWS vs vanilla throughput at each point.

use super::{run, DseConfig};
use crate::device::Device;
use crate::ir::Network;

/// One point of the Fig. 6 sweep.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    /// On-chip memory budget normalized to the reference device (the x-axis
    /// of Fig. 6).
    pub mem_scale: f64,
    /// AutoWS throughput (frames/s); `None` if infeasible.
    pub autows_fps: Option<f64>,
    /// Vanilla layer-pipelined throughput (frames/s); `None` if infeasible —
    /// the region left of the feasibility wall in Fig. 6.
    pub vanilla_fps: Option<f64>,
    /// Fraction of weight bits held off-chip in the AutoWS design.
    pub autows_offchip_frac: f64,
}

/// Run the Fig. 6 sweep: `scales` are multiples of the device's on-chip
/// memory (e.g. 0.25 ..= 2.0), with LUT/DSP/bandwidth pinned to the
/// reference device.
pub fn mem_sweep(network: &Network, device: &Device, scales: &[f64]) -> Vec<SweepPoint> {
    scales
        .iter()
        .map(|&s| {
            let dev = device.with_mem_scale(s);
            let autows = run(network, &dev, &DseConfig::default());
            let vanilla = run(network, &dev, &DseConfig::vanilla());
            let frac = autows.as_ref().map_or(0.0, |r| {
                let total: u64 = network.layers.iter().map(|l| l.weight_bits()).sum();
                let off: f64 = r
                    .design
                    .cfgs
                    .iter()
                    .zip(&network.layers)
                    .map(|(c, l)| {
                        if l.has_weights() {
                            c.frag.off_chip_ratio() * l.weight_bits() as f64
                        } else {
                            0.0
                        }
                    })
                    .sum();
                off / total as f64
            });
            SweepPoint {
                mem_scale: s,
                autows_fps: autows.map(|r| r.throughput),
                vanilla_fps: vanilla.map(|r| r.throughput),
                autows_offchip_frac: frac,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::Quant;
    use crate::models;

    /// The three regions of Fig. 6 on a coarse grid: below the wall vanilla
    /// is infeasible while AutoWS still delivers; above it they converge.
    #[test]
    fn fig6_regions_exist() {
        let net = models::resnet18(Quant::W4A5);
        let dev = Device::zcu102();
        let pts = mem_sweep(&net, &dev, &[0.4, 0.8, 1.6]);

        // smallest budget: vanilla infeasible, AutoWS feasible
        assert!(pts[0].vanilla_fps.is_none(), "vanilla should not fit at 0.4x");
        assert!(pts[0].autows_fps.is_some(), "AutoWS must fit at 0.4x");

        // AutoWS throughput is monotone (non-decreasing) in memory budget
        let fps: Vec<f64> = pts.iter().map(|p| p.autows_fps.unwrap()).collect();
        assert!(fps[0] <= fps[2] * 1.05, "{fps:?}");

        // largest budget: both feasible and close (compute-bound region)
        if let (Some(a), Some(v)) = (pts[2].autows_fps, pts[2].vanilla_fps) {
            assert!(a >= v * 0.8, "AutoWS {a} should not trail vanilla {v} by much");
        }

        // off-chip share shrinks as memory grows
        assert!(pts[0].autows_offchip_frac >= pts[2].autows_offchip_frac);
    }
}
