//! Parameter-sweep harnesses: the Fig. 6 memory sweep plus the generic
//! multi-core sweep driver the figure/hyperparameter/device grids run on.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;

use crate::device::Device;
use crate::ir::Network;

/// Fan independent sweep cases across the machine's cores with
/// `std::thread::scope` (§Perf: a (model × device × hyperparameter) grid is
/// embarrassingly parallel, and each DSE case is compute-bound).
///
/// Work-stealing over an atomic index keeps long cases from serializing the
/// tail; results come back in input order regardless of completion order, so
/// callers observe exactly the sequential semantics. `f` receives
/// `(case index, &case)`. Panics in `f` propagate to the caller.
pub fn parallel_cases<T, R, F>(cases: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let n = cases.len();
    let workers = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1).min(n);
    if workers <= 1 {
        return cases.iter().enumerate().map(|(i, c)| f(i, c)).collect();
    }

    let next = AtomicUsize::new(0);
    let (tx, rx) = mpsc::channel::<(usize, R)>();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            let tx = tx.clone();
            let next = &next;
            let f = &f;
            scope.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                // the receiver outlives the scope; send cannot fail unless
                // the main thread already panicked
                let _ = tx.send((i, f(i, &cases[i])));
            });
        }
    });
    drop(tx);

    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    for (i, r) in rx {
        out[i] = Some(r);
    }
    out.into_iter().map(|r| r.expect("every case produces a result")).collect()
}

/// One point of the Fig. 6 sweep.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    /// On-chip memory budget normalized to the reference device (the x-axis
    /// of Fig. 6).
    pub mem_scale: f64,
    /// AutoWS throughput (frames/s); `None` if infeasible.
    pub autows_fps: Option<f64>,
    /// Vanilla layer-pipelined throughput (frames/s); `None` if infeasible —
    /// the region left of the feasibility wall in Fig. 6.
    pub vanilla_fps: Option<f64>,
    /// Fraction of weight bits held off-chip in the AutoWS design.
    pub autows_offchip_frac: f64,
}

/// Run the Fig. 6 sweep: `scales` are multiples of the device's on-chip
/// memory (e.g. 0.25 ..= 2.0), with LUT/DSP/bandwidth pinned to the
/// reference device. Convenience wrapper over
/// [`crate::pipeline::sweep::mem_sweep`] — points fan across cores via
/// [`parallel_cases`] and share the pipeline design cache; results are
/// identical to the sequential uncached sweep (DSE is deterministic).
pub fn mem_sweep(network: &Network, device: &Device, scales: &[f64]) -> Vec<SweepPoint> {
    crate::pipeline::sweep::mem_sweep(
        &crate::pipeline::Planned::from_parts(network.clone(), device.clone()),
        scales,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::{run, DseConfig};
    use crate::ir::Quant;
    use crate::models;

    /// The three regions of Fig. 6 on a coarse grid: below the wall vanilla
    /// is infeasible while AutoWS still delivers; above it they converge.
    #[test]
    fn fig6_regions_exist() {
        let net = models::resnet18(Quant::W4A5);
        let dev = Device::zcu102();
        let pts = mem_sweep(&net, &dev, &[0.4, 0.8, 1.6]);

        // smallest budget: vanilla infeasible, AutoWS feasible
        assert!(pts[0].vanilla_fps.is_none(), "vanilla should not fit at 0.4x");
        assert!(pts[0].autows_fps.is_some(), "AutoWS must fit at 0.4x");

        // AutoWS throughput is monotone (non-decreasing) in memory budget
        let fps: Vec<f64> = pts.iter().map(|p| p.autows_fps.unwrap()).collect();
        assert!(fps[0] <= fps[2] * 1.05, "{fps:?}");

        // largest budget: both feasible and close (compute-bound region)
        if let (Some(a), Some(v)) = (pts[2].autows_fps, pts[2].vanilla_fps) {
            assert!(a >= v * 0.8, "AutoWS {a} should not trail vanilla {v} by much");
        }

        // off-chip share shrinks as memory grows
        assert!(pts[0].autows_offchip_frac >= pts[2].autows_offchip_frac);
    }

    #[test]
    fn parallel_cases_preserves_order_and_coverage() {
        let cases: Vec<u64> = (0..37).collect();
        let out = parallel_cases(&cases, |i, &c| {
            assert_eq!(i as u64, c);
            c * c
        });
        assert_eq!(out.len(), cases.len());
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, (i * i) as u64);
        }
    }

    #[test]
    fn parallel_cases_handles_empty_and_single() {
        let empty: Vec<u32> = Vec::new();
        assert!(parallel_cases(&empty, |_, &c| c).is_empty());
        assert_eq!(parallel_cases(&[7u32], |_, &c| c + 1), vec![8]);
    }

    #[test]
    fn parallel_sweep_matches_sequential() {
        let net = models::toy_cnn(Quant::W8A8);
        let dev = Device::zcu102();
        let scales = [0.6, 1.0, 1.4];
        let par = mem_sweep(&net, &dev, &scales);
        // sequential reference
        let seq: Vec<Option<f64>> = scales
            .iter()
            .map(|&s| run(&net, &dev.with_mem_scale(s), &DseConfig::default()).map(|r| r.throughput))
            .collect();
        for (p, s) in par.iter().zip(&seq) {
            assert_eq!(p.autows_fps, *s, "parallel and sequential sweeps must agree");
        }
    }
}
