//! Multi-device partitioning: shard one layer-wise pipeline across a chain
//! of devices connected by streaming links.
//!
//! A partition is a **contiguous** layer range — the inter-device link is a
//! FIFO carrying the boundary activations, exactly like the on-chip FIFOs
//! between CEs, so the chain stays a pipeline end to end. Cut points are
//! restricted to positions no residual skip edge crosses (a skip FIFO
//! cannot span devices), which for the ResNet-style models means block
//! boundaries.
//!
//! The search balances a max-stage-latency objective: every candidate cut
//! vector runs the per-partition greedy DSE (paper Algorithm 1, the
//! incremental [`super::Design`] engine) and the winner maximizes the
//! chain's steady-state throughput
//!
//! ```text
//! θ_chain = min( min_p θ_p ,  min_links  link_bw / boundary_bits )
//! ```
//!
//! with total BRAM as the tie-break. Candidate partitions fan across cores
//! via [`super::parallel_cases`]; every evaluated `(range, device)` pair is
//! memoized inside one search so overlapping cut vectors share DSE runs.
//!
//! The single-device deployment is the trivial 1-partition case: the whole
//! network, unrenamed, through the unchanged `dse::run` — bit-identical to
//! the non-partitioned path (enforced by `tests/partitioned_deploy.rs`).

use std::collections::HashMap;

use super::{parallel_cases, run, DseConfig, DseResult};
use crate::device::Device;
use crate::ir::Network;

/// Cap on the number of cut vectors a search evaluates; beyond it the valid
/// cut list is thinned evenly (deterministically) to keep the search
/// tractable on deep networks with many devices.
const MAX_COMBOS: u128 = 1024;

/// One stage of a sharded deployment: a contiguous layer range mapped onto
/// one device, with its DSE outcome.
#[derive(Debug, Clone)]
pub struct PartitionPlan {
    /// Layer range `[lo, hi)` in the original network's indexing.
    pub lo: usize,
    pub hi: usize,
    pub device: Device,
    pub result: DseResult,
}

impl PartitionPlan {
    /// Number of layers in this partition.
    pub fn len(&self) -> usize {
        self.hi - self.lo
    }

    pub fn is_empty(&self) -> bool {
        self.hi == self.lo
    }
}

/// Outcome of a partitioned DSE: one [`PartitionPlan`] per device plus the
/// chain-level metrics.
#[derive(Debug, Clone)]
pub struct PartitionedResult {
    /// One plan per device, in chain order.
    pub parts: Vec<PartitionPlan>,
    /// Interior cut points (empty for the 1-partition case).
    pub cuts: Vec<usize>,
    /// Steady-state chain throughput in samples/s: the slowest of the
    /// per-partition bottlenecks and the per-link rate caps.
    pub throughput: f64,
    /// Activation bits crossing each inter-device boundary, per sample.
    pub boundary_bits: Vec<u64>,
}

impl PartitionedResult {
    /// Analytic single-sample latency through the whole chain, ms: each
    /// partition's fill + one bottleneck drain, plus each link's transport
    /// latency and per-sample transfer time. Devices come from the plans
    /// themselves, so the figure can never be computed against a mismatched
    /// device list.
    pub fn latency_ms(&self) -> f64 {
        let mut total = 0.0;
        for (i, p) in self.parts.iter().enumerate() {
            total += p.result.design.latency_ms(1);
            if i + 1 < self.parts.len() {
                let (tx, rx) = (&p.device, &self.parts[i + 1].device);
                let bw = link_bandwidth(tx, rx);
                let lat = link_latency(tx, rx);
                total += (self.boundary_bits[i] as f64 / bw + lat) * 1e3;
            }
        }
        total
    }
}

/// Activation bits per sample a layer's output stream carries — THE
/// boundary-traffic formula (the DSE objective, the report and the
/// simulator all derive link load from this one definition).
pub fn layer_boundary_bits(layer: &crate::ir::Layer) -> u64 {
    layer.output_count() * layer.quant.a_bits as u64
}

/// Activation bits per sample crossing a cut at position `cut` (the output
/// of layer `cut - 1`).
pub fn boundary_bits(network: &Network, cut: usize) -> u64 {
    layer_boundary_bits(&network.layers[cut - 1])
}

/// The link between two chained devices runs at the slower endpoint's rate.
pub fn link_bandwidth(tx: &Device, rx: &Device) -> f64 {
    tx.link_bandwidth_bps.min(rx.link_bandwidth_bps).max(1.0)
}

/// One-way hop latency between two chained devices (the slower endpoint's
/// serialization dominates).
pub fn link_latency(tx: &Device, rx: &Device) -> f64 {
    tx.link_latency_s.max(rx.link_latency_s)
}

/// Cut positions (`1..L`) that no residual skip edge crosses: a cut at `c`
/// is valid iff no layer at index `j >= c` references `skip_from < c`.
pub fn valid_cuts(network: &Network) -> Vec<usize> {
    let l = network.layers.len();
    let mut cuts = Vec::new();
    'pos: for c in 1..l {
        for layer in &network.layers[c..] {
            if matches!(layer.skip_from, Some(s) if s < c) {
                continue 'pos;
            }
        }
        cuts.push(c);
    }
    cuts
}

/// Extract the `[lo, hi)` layer range as a standalone network. The full
/// range returns the network unchanged (name included), so the 1-partition
/// case is content-identical to the original. Skip back-references are
/// rebased; a range that severs one is a caller bug and panics.
pub fn subnetwork(network: &Network, lo: usize, hi: usize) -> Network {
    assert!(lo < hi && hi <= network.layers.len(), "bad partition range {lo}..{hi}");
    if lo == 0 && hi == network.layers.len() {
        return network.clone();
    }
    let first = &network.layers[lo];
    let mut sub = Network::new(
        format!("{}.l{}-{}", network.name, lo, hi),
        (first.c_in, first.h_in, first.w_in),
        network.quant,
    );
    for layer in &network.layers[lo..hi] {
        let mut l = layer.clone();
        l.skip_from = l.skip_from.map(|s| {
            assert!(s >= lo, "partition {lo}..{hi} severs a skip edge from layer {s}");
            s - lo
        });
        sub.push_unchecked(l);
    }
    sub
}

/// `n choose r` with saturation (only compared against [`MAX_COMBOS`]).
fn choose(n: usize, r: usize) -> u128 {
    if r > n {
        return 0;
    }
    let mut acc: u128 = 1;
    for i in 0..r {
        acc = acc.saturating_mul((n - i) as u128) / (i as u128 + 1);
        if acc > MAX_COMBOS * 1024 {
            return u128::MAX;
        }
    }
    acc
}

/// Thin `cuts` evenly to the largest prefix size whose `choose(.., r)` stays
/// under [`MAX_COMBOS`]; deterministic, keeps first and last candidates.
fn thin_cuts(cuts: &[usize], r: usize) -> Vec<usize> {
    let mut target = cuts.len();
    while target > r && choose(target, r) > MAX_COMBOS {
        target -= 1;
    }
    if target >= cuts.len() {
        return cuts.to_vec();
    }
    (0..target)
        .map(|i| cuts[i * (cuts.len() - 1) / (target - 1).max(1)])
        .collect()
}

/// All ascending `r`-combinations of `cuts` (bounded by [`thin_cuts`]).
fn combinations(cuts: &[usize], r: usize) -> Vec<Vec<usize>> {
    fn rec(cuts: &[usize], r: usize, start: usize, cur: &mut Vec<usize>, out: &mut Vec<Vec<usize>>) {
        if cur.len() == r {
            out.push(cur.clone());
            return;
        }
        let need = r - cur.len();
        for i in start..=cuts.len().saturating_sub(need) {
            cur.push(cuts[i]);
            rec(cuts, r, i + 1, cur, out);
            cur.pop();
        }
    }
    if cuts.len() < r {
        return Vec::new();
    }
    let mut out = Vec::new();
    rec(cuts, r, 0, &mut Vec::new(), &mut out);
    out
}

/// Layer ranges of one cut vector: `[0, c1), [c1, c2), …, [c_last, L)`.
fn ranges(cuts: &[usize], total: usize) -> Vec<(usize, usize)> {
    let mut bounds = Vec::with_capacity(cuts.len() + 2);
    bounds.push(0);
    bounds.extend_from_slice(cuts);
    bounds.push(total);
    bounds.windows(2).map(|w| (w[0], w[1])).collect()
}

/// Chain throughput of a feasible cut vector given its per-partition
/// results: the slowest partition bottleneck, further capped by every
/// inter-device link's sustainable sample rate.
fn chain_throughput(network: &Network, devices: &[Device], cuts: &[usize], thetas: &[f64]) -> f64 {
    let mut theta = f64::INFINITY;
    for &t in thetas {
        theta = theta.min(t);
    }
    for (i, &c) in cuts.iter().enumerate() {
        let bits = boundary_bits(network, c) as f64;
        let cap = link_bandwidth(&devices[i], &devices[i + 1]) / bits;
        theta = theta.min(cap);
    }
    theta
}

/// Check an explicit cut vector's shape and legality against a network and
/// a device count; the error string names the problem. Callers that accept
/// user-pinned cuts surface this as a usage error *before* any DSE runs or
/// cache writes — a malformed vector is an argument bug, not infeasibility.
pub fn validate_cuts(
    network: &Network,
    device_count: usize,
    cuts: &[usize],
) -> Result<(), String> {
    let l = network.layers.len();
    if device_count == 0 {
        return Err("the device chain is empty".to_string());
    }
    if cuts.len() + 1 != device_count {
        return Err(format!(
            "{} cut(s) given for {} device(s); a chain of k devices needs k-1 cuts",
            cuts.len(),
            device_count
        ));
    }
    if !cuts.windows(2).all(|w| w[0] < w[1]) {
        return Err(format!("cuts {cuts:?} must be strictly ascending"));
    }
    if let Some(&c) = cuts.iter().find(|&&c| c == 0 || c >= l) {
        return Err(format!("cut {c} out of range (1..{l})"));
    }
    let legal = valid_cuts(network);
    if let Some(&c) = cuts.iter().find(|&&c| !legal.contains(&c)) {
        return Err(format!(
            "cut {c} severs a residual skip edge (legal cuts: {legal:?})"
        ));
    }
    Ok(())
}

/// Evaluate an explicit cut vector: one DSE per partition (in parallel).
/// Returns `None` when the vector is malformed (see [`validate_cuts`]) or
/// any partition is infeasible on its device.
pub fn partition_with_cuts(
    network: &Network,
    devices: &[Device],
    cuts: &[usize],
    cfg: &DseConfig,
) -> Option<PartitionedResult> {
    let l = network.layers.len();
    if validate_cuts(network, devices.len(), cuts).is_err() {
        return None;
    }
    let rs = ranges(cuts, l);
    let cases: Vec<(usize, usize, usize)> =
        rs.iter().enumerate().map(|(d, &(lo, hi))| (lo, hi, d)).collect();
    let evals = parallel_cases(&cases, |_, &(lo, hi, d)| {
        run(&subnetwork(network, lo, hi), &devices[d], cfg)
    });
    let mut parts = Vec::with_capacity(rs.len());
    let mut thetas = Vec::with_capacity(rs.len());
    for ((&(lo, hi), dev), result) in rs.iter().zip(devices).zip(evals) {
        let result = result?;
        thetas.push(result.throughput);
        parts.push(PartitionPlan { lo, hi, device: dev.clone(), result });
    }
    let throughput = chain_throughput(network, devices, cuts, &thetas);
    let boundary = cuts.iter().map(|&c| boundary_bits(network, c)).collect();
    Some(PartitionedResult {
        parts,
        cuts: cuts.to_vec(),
        throughput,
        boundary_bits: boundary,
    })
}

/// Search the contiguous cut space for the best sharding of `network`
/// across `devices` (in chain order) and run the per-partition DSE.
///
/// Returns `None` when no cut vector yields a feasible design on every
/// device — the partitioned analogue of an infeasible design point.
pub fn partition(
    network: &Network,
    devices: &[Device],
    cfg: &DseConfig,
) -> Option<PartitionedResult> {
    let l = network.layers.len();
    let k = devices.len();
    if k == 0 || l == 0 {
        return None;
    }
    if k == 1 {
        // Trivial 1-partition case: the unchanged single-device DSE.
        let result = run(network, &devices[0], cfg)?;
        let throughput = result.throughput;
        return Some(PartitionedResult {
            parts: vec![PartitionPlan { lo: 0, hi: l, device: devices[0].clone(), result }],
            cuts: Vec::new(),
            throughput,
            boundary_bits: Vec::new(),
        });
    }

    let legal = valid_cuts(network);
    if legal.len() < k - 1 {
        return None;
    }
    let candidates = thin_cuts(&legal, k - 1);
    let combos = combinations(&candidates, k - 1);

    // Devices with identical content share DSE work: canonicalize each
    // device index to the first index holding equal content.
    let canon: Vec<usize> = devices
        .iter()
        .map(|d| devices.iter().position(|e| e == d).unwrap_or(0))
        .collect();

    // Every distinct (range, device-content) evaluation needed, in a
    // deterministic order, fanned across cores.
    let mut needed: Vec<(usize, usize, usize)> = Vec::new();
    {
        let mut seen = std::collections::HashSet::new();
        for combo in &combos {
            for (d, &(lo, hi)) in ranges(combo, l).iter().enumerate() {
                let key = (lo, hi, canon[d]);
                if seen.insert(key) {
                    needed.push(key);
                }
            }
        }
    }
    let results = parallel_cases(&needed, |_, &(lo, hi, d)| {
        run(&subnetwork(network, lo, hi), &devices[d], cfg)
    });
    let memo: HashMap<(usize, usize, usize), Option<DseResult>> =
        needed.into_iter().zip(results).collect();

    // Scan the cut vectors: maximize chain throughput, tie-break on total
    // BRAM (prefer the cheaper balanced layout), then first combo wins.
    let mut best: Option<(f64, u32, &Vec<usize>)> = None;
    'combo: for combo in &combos {
        let mut thetas = Vec::with_capacity(k);
        let mut bram = 0u32;
        for (d, &(lo, hi)) in ranges(combo, l).iter().enumerate() {
            match &memo[&(lo, hi, canon[d])] {
                Some(r) => {
                    thetas.push(r.throughput);
                    bram += r.area.bram.total();
                }
                None => continue 'combo,
            }
        }
        let theta = chain_throughput(network, devices, combo, &thetas);
        let better = match &best {
            None => true,
            Some((bt, bb, _)) => theta > *bt || (theta == *bt && bram < *bb),
        };
        if better {
            best = Some((theta, bram, combo));
        }
    }
    let (throughput, _, cuts) = best?;
    let cuts = cuts.clone();

    let parts = ranges(&cuts, l)
        .iter()
        .enumerate()
        .map(|(d, &(lo, hi))| PartitionPlan {
            lo,
            hi,
            device: devices[d].clone(),
            result: memo[&(lo, hi, canon[d])].clone().expect("best combo is feasible"),
        })
        .collect();
    let boundary = cuts.iter().map(|&c| boundary_bits(network, c)).collect();
    Some(PartitionedResult { parts, cuts, throughput, boundary_bits: boundary })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::Quant;
    use crate::models;

    #[test]
    fn valid_cuts_respect_skip_edges() {
        let net = models::resnet18(Quant::W4A5);
        let cuts = valid_cuts(&net);
        assert!(!cuts.is_empty(), "resnet18 has block-boundary cuts");
        for &c in &cuts {
            for (j, l) in net.layers.iter().enumerate().skip(c) {
                if let Some(s) = l.skip_from {
                    assert!(s >= c, "cut {c} severs skip {s}->{j}");
                }
            }
        }
        // a chain with no skips can cut anywhere
        let toy = models::toy_cnn(Quant::W8A8);
        assert_eq!(valid_cuts(&toy).len(), toy.layers.len() - 1);
    }

    #[test]
    fn subnetwork_full_range_is_identity() {
        let net = models::resnet18(Quant::W4A5);
        let sub = subnetwork(&net, 0, net.layers.len());
        assert_eq!(sub.name, net.name);
        assert_eq!(sub.layers.len(), net.layers.len());
        assert_eq!(
            crate::ir::serialize_network(&sub),
            crate::ir::serialize_network(&net),
            "full-range subnetwork must be content-identical"
        );
    }

    #[test]
    fn subnetwork_rebases_skips_and_shapes() {
        let net = models::resnet18(Quant::W4A5);
        let cuts = valid_cuts(&net);
        let mid = cuts[cuts.len() / 2];
        let tail = subnetwork(&net, mid, net.layers.len());
        assert_eq!(tail.input_shape.0, net.layers[mid].c_in);
        for (j, l) in tail.layers.iter().enumerate() {
            if let Some(s) = l.skip_from {
                assert!(s < j, "rebased skip must stay backwards");
            }
        }
        // partition stats add up to the whole
        let head = subnetwork(&net, 0, mid);
        assert_eq!(
            head.stats().params + tail.stats().params,
            net.stats().params,
            "partitions must cover every weight exactly once"
        );
    }

    #[test]
    fn one_partition_matches_direct_dse() {
        let net = models::resnet18(Quant::W4A5);
        let dev = Device::zcu102();
        let cfg = DseConfig::default();
        let direct = run(&net, &dev, &cfg).unwrap();
        let p = partition(&net, std::slice::from_ref(&dev), &cfg).unwrap();
        assert_eq!(p.parts.len(), 1);
        assert!(p.cuts.is_empty());
        assert_eq!(p.parts[0].result.design.cfgs, direct.design.cfgs);
        assert_eq!(p.parts[0].result.design.off_bits, direct.design.off_bits);
        assert_eq!(p.throughput, direct.throughput);
    }

    #[test]
    fn two_way_split_is_feasible_and_balanced() {
        let net = models::resnet18(Quant::W4A5);
        let devs = [Device::zcu102(), Device::zcu102()];
        let cfg = DseConfig::default();
        let p = partition(&net, &devs, &cfg).expect("resnet18 shards across 2x zcu102");
        assert_eq!(p.parts.len(), 2);
        assert_eq!(p.cuts.len(), 1);
        assert_eq!(p.parts[0].hi, p.parts[1].lo);
        assert_eq!(p.parts[0].lo, 0);
        assert_eq!(p.parts[1].hi, net.layers.len());
        // chain throughput is the min over stages and is at least as good as
        // the unsharded deployment (each partition has strictly more budget)
        let single = run(&net, &devs[0], &cfg).unwrap();
        assert!(
            p.throughput >= single.throughput * 0.85,
            "sharded {} vs single {}",
            p.throughput,
            single.throughput
        );
        for part in &p.parts {
            assert!(part.result.area.fits(&part.device));
        }
    }

    #[test]
    fn explicit_cuts_reject_bad_vectors() {
        let net = models::resnet18(Quant::W4A5);
        let devs = [Device::zcu102(), Device::zcu102()];
        let cfg = DseConfig::default();
        // wrong arity
        assert!(partition_with_cuts(&net, &devs, &[], &cfg).is_none());
        // out of range
        assert!(partition_with_cuts(&net, &devs, &[net.layers.len()], &cfg).is_none());
        // severing a skip edge (position 3 is inside the first block)
        assert!(partition_with_cuts(&net, &devs, &[3], &cfg).is_none());
        // a legal cut works
        let legal = valid_cuts(&net);
        let p = partition_with_cuts(&net, &devs, &legal[legal.len() / 2..][..1], &cfg);
        assert!(p.is_some());
    }

    #[test]
    fn combinatorics_helpers() {
        assert_eq!(choose(5, 2), 10);
        assert_eq!(choose(3, 5), 0);
        let combos = combinations(&[1, 2, 3, 4], 2);
        assert_eq!(combos.len(), 6);
        assert_eq!(combos[0], vec![1, 2]);
        assert_eq!(ranges(&[2, 5], 9), vec![(0, 2), (2, 5), (5, 9)]);
        let thin = thin_cuts(&(1..100).collect::<Vec<_>>(), 3);
        assert!(choose(thin.len(), 3) <= MAX_COMBOS);
        assert!(thin.first() == Some(&1));
    }
}
