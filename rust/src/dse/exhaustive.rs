//! Exhaustive memory allocation — the optimality reference for the greedy
//! ΔB criterion.
//!
//! With the compute allocation fixed, the memory sub-problem is: choose a
//! per-layer eviction amount so the on-chip memory fits the budget with
//! minimal total streaming bandwidth (throughput is unaffected by eviction
//! in the analytic model — it only burns bandwidth). The greedy pass solves
//! it by repeated min-ΔB eviction; this module solves it *exactly* over a
//! quantized grid of eviction levels, so tests and the ablation bench can
//! measure the greedy gap.

use super::{rebalance_all, write_burst_balance, Design, DseConfig};
use crate::ce::CeModel;
use crate::device::Device;

/// Outcome of the exhaustive memory search.
#[derive(Debug, Clone)]
pub struct ExhaustiveResult {
    /// Eviction level per weight layer, in `0..=levels` quanta of that
    /// layer's total depth.
    pub levels: Vec<(usize, u32)>,
    /// Total bandwidth (Eq. 6 LHS) of the optimum found.
    pub bandwidth_bps: f64,
    /// Number of assignments evaluated.
    pub evaluated: u64,
    /// The materialized best design.
    pub design: Design,
}

/// Set layer `l` to eviction level `lvl` (of `levels`): evict
/// `lvl/levels` of the layer's memory depth, burst-balanced.
fn apply_level(design: &mut Design, l: usize, lvl: u32, levels: u32, cfg: &DseConfig) {
    let model = CeModel::new(&design.network.layers[l], design.cfgs[l], design.clk_comp_mhz);
    let m_dep = model.m_dep();
    let m_wid = model.m_wid_bits();
    let off_words = m_dep * lvl as u64 / levels as u64;
    design.record_layer(l);
    design.off_bits[l] = off_words * m_wid;
    let n = if off_words == 0 { 1 } else { write_burst_balance(design, l, cfg.batch) };
    design.set_fragmentation(l, n);
}

/// Exhaustively enumerate eviction levels over all weight layers.
///
/// Complexity is `(levels+1)^W` for `W` weight layers, so this is only
/// callable for small networks (the toy CNN: W = 5). Returns `None` when no
/// assignment satisfies both the memory and bandwidth constraints.
pub fn exhaustive_memory(
    base: &Design,
    device: &Device,
    cfg: &DseConfig,
    levels: u32,
) -> Option<ExhaustiveResult> {
    let weight_layers: Vec<usize> = (0..base.len())
        .filter(|&i| base.network.layers[i].has_weights())
        .collect();
    let w = weight_layers.len();
    assert!(
        (levels as u64 + 1).pow(w as u32) <= 2_000_000,
        "exhaustive space too large: {w} weight layers at {levels} levels"
    );

    let budget = device.mem_bram_equiv();
    let mut assignment = vec![0u32; w];
    let mut evaluated = 0u64;
    let mut best: Option<(f64, Vec<u32>, Design)> = None;

    loop {
        // materialize this assignment
        let mut cand = base.clone();
        for (slot, &l) in weight_layers.iter().enumerate() {
            apply_level(&mut cand, l, assignment[slot], levels, cfg);
        }
        rebalance_all(&mut cand, cfg);
        evaluated += 1;

        if cand.mem_blocks() <= budget
            && cand.total_bandwidth() <= device.bandwidth_bps * cfg.bw_margin
        {
            let bw = cand.total_bandwidth();
            if best.as_ref().is_none_or(|(b, _, _)| bw < *b) {
                best = Some((bw, assignment.clone(), cand));
            }
        }

        // odometer increment
        let mut pos = 0;
        loop {
            if pos == w {
                let (bandwidth_bps, lv, design) = best?;
                return Some(ExhaustiveResult {
                    levels: weight_layers.into_iter().zip(lv).collect(),
                    bandwidth_bps,
                    evaluated,
                    design,
                });
            }
            if assignment[pos] < levels {
                assignment[pos] += 1;
                break;
            }
            assignment[pos] = 0;
            pos += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::{self, allocate_memory};
    use crate::ir::Quant;
    use crate::models;

    /// A toy design on a device sized so that roughly half of its static
    /// weight memory must be evicted — forcing real eviction decisions while
    /// the FIFOs/buffers still fit.
    fn tight_setup() -> (Design, Device, DseConfig) {
        let net = models::toy_cnn(Quant::W8A8);
        let full = Device::zcu102();
        let cfg = DseConfig::default();
        let d = Design::initialize(&net, &full);
        // Budget: 3 BRAM blocks fewer than the all-on-chip footprint. The
        // toy CNN's memories are deep and narrow (serial configs), so
        // eviction actually frees blocks; the margin is small enough that a
        // partial eviction of the biggest layer suffices.
        let target = d.mem_blocks() - 3;
        let scale = target as f64 / full.mem_bram_equiv() as f64;
        let dev = full.with_mem_scale(scale);
        assert!(
            d.mem_blocks() > dev.mem_bram_equiv(),
            "setup must force eviction: {} vs {}",
            d.mem_blocks(),
            dev.mem_bram_equiv()
        );
        (d, dev, cfg)
    }

    #[test]
    fn exhaustive_finds_feasible_optimum() {
        let (d, dev, cfg) = tight_setup();
        let r = exhaustive_memory(&d, &dev, &cfg, 4).expect("feasible");
        assert!(r.design.mem_blocks() <= dev.mem_bram_equiv());
        assert!(r.evaluated > 100);
        assert!(r.bandwidth_bps > 0.0);
    }

    #[test]
    fn greedy_is_near_optimal_on_toy() {
        let (d, dev, cfg) = tight_setup();
        let opt = exhaustive_memory(&d, &dev, &cfg, 4).expect("feasible");
        let mut greedy = d.clone();
        assert!(allocate_memory(&mut greedy, &dev, &cfg));
        let gap = greedy.total_bandwidth() / opt.bandwidth_bps;
        // The greedy evicts in finer quanta than the 1/4-depth grid, so it
        // can even beat the quantized optimum; it must never be >25% worse.
        assert!(gap < 1.25, "greedy bandwidth {:.3e} vs optimal {:.3e}", greedy.total_bandwidth(), opt.bandwidth_bps);
    }

    #[test]
    fn zero_levels_everywhere_when_memory_ample() {
        let net = models::toy_cnn(Quant::W8A8);
        let dev = Device::u250();
        let cfg = DseConfig::default();
        let d = Design::initialize(&net, &dev);
        let r = exhaustive_memory(&d, &dev, &cfg, 2).unwrap();
        // optimum is all-on-chip: zero bandwidth beyond β_io
        assert!(r.levels.iter().all(|&(_, lvl)| lvl == 0), "{:?}", r.levels);
        assert!(!r.design.any_streaming());
    }

    #[test]
    fn infeasible_when_bandwidth_zero() {
        let (d, dev, cfg) = tight_setup();
        let mut starved = dev.clone();
        starved.bandwidth_bps = 1.0; // effectively no off-chip bandwidth
        assert!(exhaustive_memory(&d, &starved, &cfg, 3).is_none());
    }

    #[test]
    #[should_panic(expected = "exhaustive space too large")]
    fn refuses_large_networks() {
        let net = models::resnet18(Quant::W4A5);
        let dev = Device::zcu102();
        let d = Design::initialize(&net, &dev);
        let _ = exhaustive_memory(&d, &dev, &DseConfig::default(), 6);
    }
}
