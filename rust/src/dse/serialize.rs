//! Design checkpoint format (`.design` files).
//!
//! A DSE run on a large network is the expensive step of the toolflow; this
//! serializer lets `autows dse --save out.design` persist the result and
//! `autows simulate --design out.design` (or any downstream tool) reload it
//! without re-searching. Text format, line-oriented, self-describing:
//!
//! ```text
//! # AutoWS design checkpoint v1
//! design <network-name> <device-name> clk=<mhz>
//! quant <label>
//! layer <idx> kp=<u32> cp=<u32> fp=<u32> n=<u32> u_on=<u64> u_off=<u64> off_bits=<u64>
//! ...
//! end
//! ```
//!
//! Every layer gets a `layer` line (non-weight CEs carry throughput-shaping
//! unroll factors too); the network itself is rebuilt from the zoo (or a
//! `.net` file) by name, so a checkpoint stays valid as long as the model
//! builder produces the same layer sequence — which the loader verifies
//! layer-by-layer (index range + `m_dep` geometry coverage).

use super::Design;
use crate::ce::Fragmentation;
use crate::device::Device;
use crate::ir::Network;

/// Serialization error (line number + message).
#[derive(Debug, Clone)]
pub struct DesignFormatError {
    pub line: usize,
    pub message: String,
}

impl std::fmt::Display for DesignFormatError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "design checkpoint line {}: {}", self.line, self.message)
    }
}

impl std::error::Error for DesignFormatError {}

fn err(line: usize, message: impl Into<String>) -> DesignFormatError {
    DesignFormatError { line, message: message.into() }
}

/// Serialize a design (paired with the device it was explored for).
pub fn serialize_design(design: &Design, device: &Device) -> String {
    let mut out = String::from("# AutoWS design checkpoint v1\n");
    out.push_str(&format!(
        "design {} {} clk={}\n",
        design.network.name, device.name, design.clk_comp_mhz
    ));
    out.push_str(&format!("quant {}\n", design.network.quant.label().to_ascii_lowercase()));
    // every layer: non-weight CEs (pools, eltwise) carry unroll factors
    // that shape the pipeline's throughput too
    for i in 0..design.len() {
        let c = &design.cfgs[i];
        out.push_str(&format!(
            "layer {i} kp={} cp={} fp={} n={} u_on={} u_off={} off_bits={}\n",
            c.kp, c.cp, c.fp, c.frag.n, c.frag.u_on, c.frag.u_off, design.off_bits[i]
        ));
    }
    out.push_str("end\n");
    out
}

/// Parse one `key=value` token as an integer.
fn kv(tok: &str, key: &str, line: usize) -> Result<u64, DesignFormatError> {
    let v = tok
        .strip_prefix(key)
        .and_then(|r| r.strip_prefix('='))
        .ok_or_else(|| err(line, format!("expected `{key}=<int>`, got `{tok}`")))?;
    v.parse().map_err(|_| err(line, format!("{key}: cannot parse `{v}`")))
}

/// Reload a checkpoint against a freshly-built `network` and `device`.
///
/// The (network, device) pair must match what the checkpoint records — the
/// loader cross-checks names, layer indices and memory geometry so a stale
/// checkpoint fails loudly instead of simulating garbage.
pub fn parse_design(
    text: &str,
    network: &Network,
    device: &Device,
) -> Result<Design, DesignFormatError> {
    let mut design = Design::initialize(network, device);
    let mut seen_header = false;
    let mut seen_end = false;

    for (idx, raw) in text.lines().enumerate() {
        let line_no = idx + 1;
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        if seen_end {
            return Err(err(line_no, "content after `end`"));
        }
        let toks: Vec<&str> = line.split_whitespace().collect();
        match toks[0] {
            "design" => {
                if toks.len() < 3 {
                    return Err(err(line_no, "usage: design <network> <device> clk=<mhz>"));
                }
                if toks[1] != network.name {
                    return Err(err(
                        line_no,
                        format!("checkpoint is for `{}`, not `{}`", toks[1], network.name),
                    ));
                }
                if toks[2] != device.name {
                    return Err(err(
                        line_no,
                        format!("checkpoint is for device `{}`, not `{}`", toks[2], device.name),
                    ));
                }
                seen_header = true;
            }
            "quant" => {
                let label = toks.get(1).copied().unwrap_or("");
                let expect = network.quant.label().to_ascii_lowercase();
                if label != expect {
                    return Err(err(
                        line_no,
                        format!("checkpoint quant `{label}` != network quant `{expect}`"),
                    ));
                }
            }
            "layer" => {
                if !seen_header {
                    return Err(err(line_no, "`layer` before `design` header"));
                }
                if toks.len() != 9 {
                    return Err(err(line_no, "layer line needs 8 fields"));
                }
                let i = toks[1]
                    .parse::<usize>()
                    .map_err(|_| err(line_no, "bad layer index"))?;
                if i >= network.layers.len() {
                    return Err(err(line_no, format!("layer {i} out of range")));
                }
                let kp = kv(toks[2], "kp", line_no)? as u32;
                let cp = kv(toks[3], "cp", line_no)? as u32;
                let fp = kv(toks[4], "fp", line_no)? as u32;
                let n = kv(toks[5], "n", line_no)? as u32;
                let u_on = kv(toks[6], "u_on", line_no)?;
                let u_off = kv(toks[7], "u_off", line_no)?;
                let off_bits = kv(toks[8], "off_bits", line_no)?;
                if kp == 0 || cp == 0 || fp == 0 || n == 0 {
                    return Err(err(line_no, "unroll factors and n must be positive"));
                }
                if !network.layers[i].has_weights() && (u_off > 0 || off_bits > 0) {
                    return Err(err(
                        line_no,
                        format!("layer {i} carries no weights but records eviction"),
                    ));
                }
                design.cfgs[i].kp = kp;
                design.cfgs[i].cp = cp;
                design.cfgs[i].fp = fp;
                design.cfgs[i].frag = Fragmentation { n, u_on, u_off };
                design.off_bits[i] = off_bits;
                // geometry cross-check: the recorded fragmentation must
                // cover this layer's memory depth at these unrolls
                let m_dep = crate::ce::CeModel::new(
                    &network.layers[i],
                    design.cfgs[i],
                    design.clk_comp_mhz,
                )
                .m_dep();
                if design.cfgs[i].frag.m_dep() < m_dep {
                    return Err(err(
                        line_no,
                        format!(
                            "layer {i}: fragmentation covers {} words, memory needs {m_dep}",
                            design.cfgs[i].frag.m_dep()
                        ),
                    ));
                }
                design.refresh(i);
            }
            "end" => seen_end = true,
            other => return Err(err(line_no, format!("unknown record `{other}`"))),
        }
    }
    if !seen_header {
        return Err(err(text.lines().count().max(1), "missing `design` header"));
    }
    if !seen_end {
        return Err(err(text.lines().count().max(1), "missing `end` (truncated file?)"));
    }
    Ok(design)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::{self, DseConfig};
    use crate::ir::Quant;
    use crate::models;

    fn designed() -> (Design, Device, Network) {
        let net = models::resnet18(Quant::W4A5);
        let dev = Device::zcu102();
        let r = dse::run(&net, &dev, &DseConfig::default()).unwrap();
        (r.design, dev, net)
    }

    #[test]
    fn roundtrip_preserves_everything() {
        let (d, dev, net) = designed();
        let text = serialize_design(&d, &dev);
        let back = parse_design(&text, &net, &dev).unwrap();
        assert_eq!(d.cfgs, back.cfgs);
        assert_eq!(d.off_bits, back.off_bits);
        assert_eq!(d.min_throughput(), back.min_throughput());
        assert_eq!(d.total_area(), back.total_area());
        // relative: `d` carries the rounding residue of its incremental
        // bandwidth aggregate, `back` was rebuilt in one clean pass
        let rel = (d.total_bandwidth() - back.total_bandwidth()).abs() / d.total_bandwidth();
        assert!(rel < 1e-9, "bandwidth round-trip drift {rel}");
    }

    #[test]
    fn wrong_network_rejected() {
        let (d, dev, _) = designed();
        let text = serialize_design(&d, &dev);
        let other = models::toy_cnn(Quant::W4A5);
        let e = parse_design(&text, &other, &dev).unwrap_err();
        assert!(e.message.contains("checkpoint is for"), "{e}");
    }

    #[test]
    fn wrong_device_rejected() {
        let (d, dev, net) = designed();
        let text = serialize_design(&d, &dev);
        let e = parse_design(&text, &net, &Device::u50()).unwrap_err();
        assert!(e.message.contains("device"), "{e}");
    }

    #[test]
    fn wrong_quant_rejected() {
        let (d, dev, _) = designed();
        let text = serialize_design(&d, &dev);
        let net8 = models::resnet18(Quant::W8A8);
        let e = parse_design(&text, &net8, &dev).unwrap_err();
        assert!(e.message.contains("quant"), "{e}");
    }

    #[test]
    fn truncated_file_rejected() {
        let (d, dev, net) = designed();
        let text = serialize_design(&d, &dev);
        let cut = &text[..text.len() - 5];
        let e = parse_design(cut, &net, &dev).unwrap_err();
        assert!(e.message.contains("truncated") || e.message.contains("end"), "{e}");
    }

    #[test]
    fn corrupted_geometry_rejected() {
        let (d, dev, net) = designed();
        let text = serialize_design(&d, &dev).replace("u_on=", "u_on=0 # was: u_on=");
        // zeroing u_on shrinks coverage below m_dep for on-chip layers
        assert!(parse_design(&text, &net, &dev).is_err());
    }

    #[test]
    fn garbage_never_panics() {
        let (_, dev, net) = designed();
        for bad in [
            "",
            "design resnet18 zcu102 clk=250",
            "layer 0 kp=1 cp=1 fp=1 n=1 u_on=5 u_off=0 off_bits=0",
            "design resnet18 zcu102 clk=250\nlayer 999 kp=1 cp=1 fp=1 n=1 u_on=5 u_off=0 off_bits=0\nend",
            "design resnet18 zcu102 clk=250\nblorp\nend",
        ] {
            assert!(parse_design(bad, &net, &dev).is_err(), "{bad:?}");
        }
    }
}
