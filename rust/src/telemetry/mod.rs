//! Unified telemetry: lock-free serving spans, process-wide counters, and
//! exposition formats.
//!
//! Three layers, strictly separated by cost:
//!
//! 1. **Recording** ([`spans`], [`registry`]) — what the hot paths touch.
//!    Span rings are single-writer seqlocks (no locks, no allocation);
//!    counters are relaxed atomics. The
//!    [`serving_path_locks`](crate::coordinator::Server::serving_path_locks)
//!    tripwire stays 0 with telemetry on.
//! 2. **Snapshot** ([`TelemetrySnapshot`]) — taken on demand from
//!    `Server::telemetry()` / `Router::telemetry()` / registry terminals.
//!    Folds the coordinator's batch-event metrics, the global counter
//!    registry (DSE + sim + design cache), and every span ring.
//! 3. **Exposition** ([`export`]) — pure formatters over a snapshot:
//!    Prometheus text, JSON, and Chrome trace-event (Perfetto) JSON.
//!    Deterministic ordering, so the formats are golden-tested.
//!
//! The CLI surfaces all of it: `autows serve --metrics-out PATH
//! --trace-out PATH --stats-interval SECS` and
//! `autows simulate --trace-out x.csv|x.json|x.txt`.

mod export;
mod registry;
mod spans;

pub use export::{
    chrome_trace_sim, chrome_trace_spans, json_snapshot, prometheus_text, span_stats, SpanStats,
};
pub use registry::{counters, Counter, GlobalCounters};
pub use spans::{Span, SpanKind, SpanRing, SpanScribe, SHARD_LANE_BASE};

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use crate::coordinator::{MetricsHandle, MetricsSnapshot};

/// Spans retained per lane ring. Small enough that an idle hub costs a few
/// dozen KB, large enough to cover the recent window every exporter cares
/// about.
pub const DEFAULT_SPAN_CAPACITY: usize = 1024;

/// Owns the span rings for one serving session: one ring per pool worker
/// (lane = worker index) and one per batcher shard (lane =
/// [`SHARD_LANE_BASE`]` + shard`). Created at boot, before any traffic, so
/// the hot path never allocates or locks to reach its ring.
pub struct TelemetryHub {
    epoch: Instant,
    workers: usize,
    rings: Vec<Arc<SpanRing>>,
}

impl TelemetryHub {
    /// A hub for `workers` pool lanes and `shards` batcher lanes.
    pub fn new(workers: usize, shards: usize, capacity: usize) -> TelemetryHub {
        let epoch = Instant::now();
        let mut rings = Vec::with_capacity(workers + shards);
        for w in 0..workers {
            rings.push(Arc::new(SpanRing::new(w as u32, capacity)));
        }
        for s in 0..shards {
            rings.push(Arc::new(SpanRing::new(SHARD_LANE_BASE + s as u32, capacity)));
        }
        TelemetryHub { epoch, workers, rings }
    }

    /// The instant all span timestamps are relative to.
    pub fn epoch(&self) -> Instant {
        self.epoch
    }

    /// Recording handle for pool worker `w`. Hand exactly one out per
    /// lane — the rings are single-writer.
    pub(crate) fn worker_scribe(&self, w: usize) -> SpanScribe {
        SpanScribe::new(Arc::clone(&self.rings[w]), self.epoch)
    }

    /// Recording handle for batcher shard `s`.
    pub(crate) fn shard_scribe(&self, s: usize) -> SpanScribe {
        SpanScribe::new(Arc::clone(&self.rings[self.workers + s]), self.epoch)
    }

    /// Every ring-resident span, ordered by lane then recording order.
    /// Lock-free with respect to the writers.
    pub fn spans(&self) -> Vec<Span> {
        let mut out = Vec::new();
        for ring in &self.rings {
            out.extend(ring.snapshot());
        }
        out
    }

    /// Total spans ever recorded across all lanes.
    pub fn recorded(&self) -> u64 {
        self.rings.iter().map(|r| r.recorded()).sum()
    }
}

/// One coherent observation of a serving session: folded request metrics,
/// the process-wide counter registry, and the resident spans. The
/// exposition formatters ([`prometheus_text`], [`json_snapshot`],
/// [`chrome_trace_spans`]) are pure functions of this value.
#[derive(Debug, Clone)]
pub struct TelemetrySnapshot {
    pub metrics: MetricsSnapshot,
    /// `(name, value)` pairs in stable name order — see
    /// [`counters_snapshot`].
    pub counters: Vec<(String, u64)>,
    pub spans: Vec<Span>,
}

/// Read every process-wide counter: the design cache's per-schema
/// hit/miss atomics and the [`counters`] registry (DSE search + simulator
/// fast-forward). Stable name order; purely relaxed loads.
pub fn counters_snapshot() -> Vec<(String, u64)> {
    let cache = crate::pipeline::design_cache().stats();
    let g = counters();
    let pairs: [(&str, u64); 19] = [
        ("cache_entries", cache.entries as u64),
        ("cache_hits", cache.hits),
        ("cache_hits_colocated", cache.colocated_hits),
        ("cache_hits_fleet", cache.fleet_hits),
        ("cache_hits_partitioned", cache.partitioned_hits),
        ("cache_hits_single", cache.single_hits),
        ("cache_misses", cache.misses),
        ("cache_misses_colocated", cache.colocated_misses),
        ("cache_misses_fleet", cache.fleet_misses),
        ("cache_misses_partitioned", cache.partitioned_misses),
        ("cache_misses_single", cache.single_misses),
        ("dse_greedy_steps", g.dse_greedy_steps.get()),
        ("dse_heap_pops", g.dse_heap_pops.get()),
        ("dse_trial_rollbacks", g.dse_trial_rollbacks.get()),
        ("sim_events", g.sim_events.get()),
        ("sim_events_processed", g.sim_events_processed.get()),
        ("sim_fast_forwards", g.sim_fast_forwards.get()),
        ("sim_rounds_skipped", g.sim_rounds_skipped.get()),
        ("sim_runs", g.sim_runs.get()),
    ];
    pairs.iter().map(|&(n, v)| (n.to_string(), v)).collect()
}

/// Periodic one-line stats reports to stderr during a serve session
/// (`--stats-interval`). Reads through cloneable [`MetricsHandle`]s — the
/// reporter thread never touches the `Server` itself, and snapshots are
/// the only cost it imposes.
pub struct StatsReporter {
    stop: Arc<AtomicBool>,
    thread: Option<thread::JoinHandle<()>>,
}

impl StatsReporter {
    /// Start reporting over `handles` (one per server; sums are across all
    /// of them) every `interval`.
    pub fn start(handles: Vec<MetricsHandle>, interval: Duration) -> StatsReporter {
        let stop = Arc::new(AtomicBool::new(false));
        let flag = Arc::clone(&stop);
        let started = Instant::now();
        let thread = thread::spawn(move || {
            let mut last_requests = 0u64;
            loop {
                // sleep in short slices so stop() returns promptly
                let mut slept = Duration::ZERO;
                while slept < interval {
                    if flag.load(Ordering::Relaxed) {
                        return;
                    }
                    let slice = Duration::from_millis(20).min(interval - slept);
                    thread::sleep(slice);
                    slept += slice;
                }
                if flag.load(Ordering::Relaxed) {
                    return;
                }
                let mut requests = 0u64;
                let mut batches = 0u64;
                let mut p99 = 0f64;
                let mut queue_max = 0usize;
                let mut locks = 0u64;
                for h in &handles {
                    let m = h.snapshot();
                    requests += m.requests;
                    batches += m.batches;
                    p99 = p99.max(m.p99_ms);
                    queue_max = queue_max.max(m.queue_depth_max);
                    locks += h.serving_path_locks();
                }
                eprintln!(
                    "[autows stats +{}s] requests={requests} (+{}) batches={batches} p99={p99:.2}ms queue_max={queue_max} locks={locks}",
                    started.elapsed().as_secs(),
                    requests.saturating_sub(last_requests),
                );
                last_requests = requests;
            }
        });
        StatsReporter { stop, thread: Some(thread) }
    }

    /// Stop and join the reporter thread.
    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for StatsReporter {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hub_lanes_are_disjoint_and_ordered() {
        let hub = TelemetryHub::new(2, 2, 8);
        hub.worker_scribe(0).mark(SpanKind::Engine, 1);
        hub.worker_scribe(1).mark(SpanKind::Engine, 2);
        hub.shard_scribe(0).mark(SpanKind::Batch, 3);
        hub.shard_scribe(1).mark(SpanKind::Batch, 4);
        let spans = hub.spans();
        assert_eq!(spans.len(), 4);
        assert_eq!(spans[0].lane, 0);
        assert_eq!(spans[1].lane, 1);
        assert_eq!(spans[2].lane, SHARD_LANE_BASE);
        assert_eq!(spans[3].lane, SHARD_LANE_BASE + 1);
        assert!(spans[2].is_shard_lane() && !spans[1].is_shard_lane());
        assert_eq!(hub.recorded(), 4);
    }

    #[test]
    fn counters_snapshot_is_sorted_and_complete() {
        let snap = counters_snapshot();
        assert_eq!(snap.len(), 19);
        for pair in snap.windows(2) {
            assert!(pair[0].0 < pair[1].0, "counter names must be sorted: {pair:?}");
        }
        assert!(snap.iter().any(|(n, _)| n == "dse_greedy_steps"));
        assert!(snap.iter().any(|(n, _)| n == "sim_runs"));
        assert!(snap.iter().any(|(n, _)| n == "cache_hits_single"));
    }

    #[test]
    fn stats_reporter_stops_promptly() {
        let t0 = Instant::now();
        let reporter = StatsReporter::start(Vec::new(), Duration::from_secs(60));
        thread::sleep(Duration::from_millis(30));
        reporter.stop();
        assert!(t0.elapsed() < Duration::from_secs(5));
    }
}
