//! Exposition formats: Prometheus text, JSON snapshot, and Chrome
//! trace-event (Perfetto-loadable) JSON.
//!
//! Every function here is a **pure formatter** over an already-taken
//! [`TelemetrySnapshot`] (or span/trace slice) — no globals are read, so
//! the outputs are deterministic and golden-testable. Ordering is stable
//! by construction: metric families appear in a fixed sequence, labeled
//! series iterate [`SpanKind::ALL`] / worker index / the snapshot's own
//! counter order.

use super::spans::{Span, SpanKind, SHARD_LANE_BASE};
use super::TelemetrySnapshot;
use crate::sim::TraceEvent;

/// Per-kind span aggregate (computed at exposition time, never on the
/// serving path).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SpanStats {
    pub kind: SpanKind,
    /// Spans recorded (still resident in the rings).
    pub count: u64,
    /// Requests those spans covered.
    pub items: u64,
    /// Summed duration, µs.
    pub dur_us_sum: u64,
    /// Longest single span, µs.
    pub dur_us_max: u64,
}

/// Aggregate `spans` per kind, in [`SpanKind::ALL`] order (zero-count
/// kinds included, so the exposition shape never depends on load).
pub fn span_stats(spans: &[Span]) -> Vec<SpanStats> {
    SpanKind::ALL
        .iter()
        .map(|&kind| {
            let mut s = SpanStats { kind, count: 0, items: 0, dur_us_sum: 0, dur_us_max: 0 };
            for span in spans.iter().filter(|sp| sp.kind == kind) {
                s.count += 1;
                s.items += u64::from(span.items);
                s.dur_us_sum += span.dur_us;
                s.dur_us_max = s.dur_us_max.max(span.dur_us);
            }
            s
        })
        .collect()
}

/// A finite f64 rendered as a bare number (`0` for the non-finite values
/// that cannot appear in a healthy snapshot — both formats stay parseable
/// regardless).
fn num(x: f64) -> String {
    if x.is_finite() {
        format!("{x}")
    } else {
        "0".to_string()
    }
}

/// Prometheus label-value escaping: backslash, double-quote, newline.
fn escape_label(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

/// JSON string escaping (same character set; names here are identifiers).
fn escape_json(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

fn family(out: &mut String, name: &str, help: &str, kind: &str) {
    out.push_str("# HELP ");
    out.push_str(name);
    out.push(' ');
    out.push_str(help);
    out.push_str("\n# TYPE ");
    out.push_str(name);
    out.push(' ');
    out.push_str(kind);
    out.push('\n');
}

/// Render the snapshot in the Prometheus text exposition format
/// (version 0.0.4): `# HELP`/`# TYPE` headers, one `name{labels} value`
/// sample per line, stable family and series order.
pub fn prometheus_text(t: &TelemetrySnapshot) -> String {
    let m = &t.metrics;
    let mut out = String::with_capacity(4096);

    family(&mut out, "autows_requests_total", "Requests completed by the serving session.", "counter");
    out.push_str(&format!("autows_requests_total {}\n", m.requests));
    family(&mut out, "autows_batches_total", "Engine batches executed.", "counter");
    out.push_str(&format!("autows_batches_total {}\n", m.batches));
    family(&mut out, "autows_mean_batch", "Mean requests per engine batch.", "gauge");
    out.push_str(&format!("autows_mean_batch {}\n", num(m.mean_batch)));
    family(&mut out, "autows_throughput_rps", "Achieved request throughput over the session.", "gauge");
    out.push_str(&format!("autows_throughput_rps {}\n", num(m.throughput_rps)));
    family(&mut out, "autows_latency_ms", "Request latency distribution, milliseconds.", "gauge");
    out.push_str(&format!("autows_latency_ms{{quantile=\"0.5\"}} {}\n", num(m.p50_ms)));
    out.push_str(&format!("autows_latency_ms{{quantile=\"0.95\"}} {}\n", num(m.p95_ms)));
    out.push_str(&format!("autows_latency_ms{{quantile=\"0.99\"}} {}\n", num(m.p99_ms)));
    out.push_str(&format!("autows_latency_ms{{quantile=\"mean\"}} {}\n", num(m.mean_ms)));
    family(&mut out, "autows_queue_depth", "Dispatch-point queue depth (requests admitted, not yet on an engine).", "gauge");
    out.push_str(&format!("autows_queue_depth{{stat=\"mean\"}} {}\n", num(m.queue_depth_mean)));
    out.push_str(&format!("autows_queue_depth{{stat=\"max\"}} {}\n", m.queue_depth_max));
    family(&mut out, "autows_sim_accel_seconds_total", "Simulated accelerator busy time, seconds.", "counter");
    out.push_str(&format!("autows_sim_accel_seconds_total {}\n", num(m.sim_accel_s)));

    family(&mut out, "autows_worker_batches_total", "Batches served per pool worker.", "counter");
    for (w, ws) in m.per_worker.iter().enumerate() {
        out.push_str(&format!("autows_worker_batches_total{{worker=\"{w}\"}} {}\n", ws.batches));
    }
    family(&mut out, "autows_worker_requests_total", "Requests served per pool worker.", "counter");
    for (w, ws) in m.per_worker.iter().enumerate() {
        out.push_str(&format!("autows_worker_requests_total{{worker=\"{w}\"}} {}\n", ws.requests));
    }
    family(&mut out, "autows_worker_busy_seconds_total", "Engine busy time per pool worker, seconds.", "counter");
    for (w, ws) in m.per_worker.iter().enumerate() {
        out.push_str(&format!("autows_worker_busy_seconds_total{{worker=\"{w}\"}} {}\n", num(ws.busy_s)));
    }

    let stats = span_stats(&t.spans);
    family(&mut out, "autows_spans_total", "Serving-path spans recorded per kind (ring-resident).", "counter");
    for s in &stats {
        out.push_str(&format!("autows_spans_total{{kind=\"{}\"}} {}\n", s.kind.label(), s.count));
    }
    family(&mut out, "autows_span_items_total", "Requests covered by the recorded spans, per kind.", "counter");
    for s in &stats {
        out.push_str(&format!("autows_span_items_total{{kind=\"{}\"}} {}\n", s.kind.label(), s.items));
    }
    family(&mut out, "autows_span_duration_us_sum", "Summed span duration per kind, microseconds.", "counter");
    for s in &stats {
        out.push_str(&format!("autows_span_duration_us_sum{{kind=\"{}\"}} {}\n", s.kind.label(), s.dur_us_sum));
    }
    family(&mut out, "autows_span_duration_us_max", "Longest single span per kind, microseconds.", "gauge");
    for s in &stats {
        out.push_str(&format!("autows_span_duration_us_max{{kind=\"{}\"}} {}\n", s.kind.label(), s.dur_us_max));
    }

    family(&mut out, "autows_pipeline_counter", "Process-wide DSE/simulator/design-cache counters.", "counter");
    for (name, value) in &t.counters {
        out.push_str(&format!("autows_pipeline_counter{{name=\"{}\"}} {value}\n", escape_label(name)));
    }
    out
}

/// Render the snapshot as one JSON document (machine-readable sibling of
/// [`prometheus_text`]; key order is fixed).
pub fn json_snapshot(t: &TelemetrySnapshot) -> String {
    let m = &t.metrics;
    let mut out = String::with_capacity(2048);
    out.push('{');
    out.push_str(&format!("\"requests\":{},", m.requests));
    out.push_str(&format!("\"batches\":{},", m.batches));
    out.push_str(&format!("\"mean_batch\":{},", num(m.mean_batch)));
    out.push_str(&format!("\"p50_ms\":{},", num(m.p50_ms)));
    out.push_str(&format!("\"p95_ms\":{},", num(m.p95_ms)));
    out.push_str(&format!("\"p99_ms\":{},", num(m.p99_ms)));
    out.push_str(&format!("\"mean_ms\":{},", num(m.mean_ms)));
    out.push_str(&format!("\"throughput_rps\":{},", num(m.throughput_rps)));
    out.push_str(&format!("\"sim_accel_s\":{},", num(m.sim_accel_s)));
    out.push_str(&format!("\"queue_depth_mean\":{},", num(m.queue_depth_mean)));
    out.push_str(&format!("\"queue_depth_max\":{},", m.queue_depth_max));
    out.push_str("\"per_worker\":[");
    for (w, ws) in m.per_worker.iter().enumerate() {
        if w > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "{{\"worker\":{w},\"batches\":{},\"requests\":{},\"busy_s\":{}}}",
            ws.batches,
            ws.requests,
            num(ws.busy_s)
        ));
    }
    out.push_str("],\"spans\":[");
    for (i, s) in span_stats(&t.spans).iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "{{\"kind\":\"{}\",\"count\":{},\"items\":{},\"dur_us_sum\":{},\"dur_us_max\":{}}}",
            s.kind.label(),
            s.count,
            s.items,
            s.dur_us_sum,
            s.dur_us_max
        ));
    }
    out.push_str("],\"counters\":{");
    for (i, (name, value)) in t.counters.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!("\"{}\":{value}", escape_json(name)));
    }
    out.push_str("}}\n");
    out
}

/// Display tid for a lane: workers keep their index, shard lanes map to a
/// compact 10000+ block (cosmetic — Perfetto sorts tracks by tid).
fn lane_tid(lane: u32) -> u32 {
    if lane >= SHARD_LANE_BASE {
        10_000 + (lane - SHARD_LANE_BASE)
    } else {
        lane
    }
}

/// Serialize serving spans as a Chrome trace-event JSON document
/// (load in Perfetto / `chrome://tracing`). One complete (`"X"`) event per
/// span; lanes become threads of pid 0, named via metadata events.
pub fn chrome_trace_spans(spans: &[Span]) -> String {
    let mut lanes: Vec<u32> = spans.iter().map(|s| s.lane).collect();
    lanes.sort_unstable();
    lanes.dedup();
    let mut out = String::with_capacity(256 + spans.len() * 96);
    out.push_str("{\"traceEvents\":[");
    let mut first = true;
    for &lane in &lanes {
        if !first {
            out.push(',');
        }
        first = false;
        let name = if lane >= SHARD_LANE_BASE {
            format!("shard {}", lane - SHARD_LANE_BASE)
        } else {
            format!("worker {lane}")
        };
        out.push_str(&format!(
            "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":0,\"tid\":{},\"args\":{{\"name\":\"{name}\"}}}}",
            lane_tid(lane)
        ));
    }
    for s in spans {
        if !first {
            out.push(',');
        }
        first = false;
        out.push_str(&format!(
            "{{\"name\":\"{}\",\"cat\":\"serve\",\"ph\":\"X\",\"ts\":{},\"dur\":{},\"pid\":0,\"tid\":{},\"args\":{{\"items\":{}}}}}",
            s.kind.label(),
            s.start_us,
            s.dur_us,
            lane_tid(s.lane),
            s.items
        ));
    }
    out.push_str("],\"displayTimeUnit\":\"ms\"}\n");
    out
}

/// Serialize a simulator [`TraceEvent`] stream (seconds) as Chrome
/// trace-event JSON: layers become threads, event kinds become slice
/// names, timestamps convert to µs.
pub fn chrome_trace_sim(traces: &[TraceEvent]) -> String {
    let mut layers: Vec<usize> = traces.iter().map(|t| t.layer).collect();
    layers.sort_unstable();
    layers.dedup();
    let mut out = String::with_capacity(256 + traces.len() * 96);
    out.push_str("{\"traceEvents\":[");
    let mut first = true;
    for &layer in &layers {
        if !first {
            out.push(',');
        }
        first = false;
        out.push_str(&format!(
            "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":0,\"tid\":{layer},\"args\":{{\"name\":\"layer {layer}\"}}}}"
        ));
    }
    for t in traces {
        if !first {
            out.push(',');
        }
        first = false;
        out.push_str(&format!(
            "{{\"name\":\"{}\",\"cat\":\"sim\",\"ph\":\"X\",\"ts\":{:.3},\"dur\":{:.3},\"pid\":0,\"tid\":{}}}",
            t.kind.label(),
            t.start * 1e6,
            (t.end - t.start).max(0.0) * 1e6,
            t.layer
        ));
    }
    out.push_str("],\"displayTimeUnit\":\"ms\"}\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn one_span(kind: SpanKind, lane: u32, items: u32, start_us: u64, dur_us: u64) -> Span {
        Span { kind, lane, items, start_us, dur_us }
    }

    #[test]
    fn span_stats_cover_every_kind_in_stable_order() {
        let spans = vec![
            one_span(SpanKind::Engine, 0, 4, 10, 30),
            one_span(SpanKind::Engine, 1, 2, 40, 10),
            one_span(SpanKind::Wait, 0, 4, 0, 10),
        ];
        let stats = span_stats(&spans);
        assert_eq!(stats.len(), SpanKind::ALL.len());
        assert_eq!(stats[0].kind, SpanKind::Wait);
        assert_eq!(stats[0].count, 1);
        assert_eq!(stats[1].kind, SpanKind::Engine);
        assert_eq!((stats[1].count, stats[1].items, stats[1].dur_us_sum, stats[1].dur_us_max), (2, 6, 40, 30));
        // absent kinds still appear, zeroed
        assert_eq!(stats[4].kind, SpanKind::Steal);
        assert_eq!(stats[4].count, 0);
    }

    #[test]
    fn label_escaping() {
        assert_eq!(escape_label("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
        assert_eq!(escape_json("x\"y"), "x\\\"y");
    }

    #[test]
    fn non_finite_values_stay_parseable() {
        assert_eq!(num(f64::NAN), "0");
        assert_eq!(num(f64::INFINITY), "0");
        assert_eq!(num(1.5), "1.5");
    }

    #[test]
    fn chrome_trace_sim_is_balanced_json() {
        use crate::sim::TraceKind;
        let traces = vec![
            TraceEvent { layer: 1, kind: TraceKind::WriteBurst, start: 0.0, end: 1e-6 },
            TraceEvent { layer: 1, kind: TraceKind::Stall, start: 1e-6, end: 2e-6 },
        ];
        let doc = chrome_trace_sim(&traces);
        assert!(doc.starts_with("{\"traceEvents\":["));
        assert!(doc.ends_with("],\"displayTimeUnit\":\"ms\"}\n"));
        assert_eq!(doc.matches("\"ph\":\"X\"").count(), 2);
        assert_eq!(doc.matches("\"ph\":\"M\"").count(), 1, "one thread_name per layer");
        assert!(doc.contains("\"name\":\"write\""));
        assert!(doc.contains("\"name\":\"stall\""));
        // braces balance (cheap structural check without a JSON parser)
        assert_eq!(doc.matches('{').count(), doc.matches('}').count());
    }

    #[test]
    fn chrome_trace_spans_names_worker_and_shard_lanes() {
        let spans = vec![
            one_span(SpanKind::Engine, 2, 4, 10, 30),
            one_span(SpanKind::Batch, SHARD_LANE_BASE + 1, 4, 5, 2),
        ];
        let doc = chrome_trace_spans(&spans);
        assert!(doc.contains("\"name\":\"worker 2\""));
        assert!(doc.contains("\"name\":\"shard 1\""));
        assert!(doc.contains("\"tid\":10001"));
        assert_eq!(doc.matches('{').count(), doc.matches('}').count());
    }
}
