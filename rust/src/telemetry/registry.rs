//! Process-wide named counters unifying DSE search telemetry and
//! simulator fast-forward diagnostics.
//!
//! The counters are plain relaxed atomics in a `const`-initialized static
//! — incrementing one is a few nanoseconds and never takes a lock, so the
//! DSE inner loops and the simulator can record unconditionally. The
//! design-cache hit/miss counters are NOT duplicated here: the cache keeps
//! its own per-schema atomics ([`crate::pipeline::CacheStats`]) and
//! [`crate::telemetry::counters_snapshot`] folds them in at read time.

use std::sync::atomic::{AtomicU64, Ordering};

/// A monotone relaxed counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub const fn new() -> Counter {
        Counter(AtomicU64::new(0))
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn incr(&self) {
        self.add(1);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Every process-wide counter the telemetry layer maintains.
#[derive(Debug)]
pub struct GlobalCounters {
    /// Greedy compute-allocation iterations across every DSE run
    /// (Algorithm 1 unroll increments).
    pub dse_greedy_steps: Counter,
    /// Min-ΔB eviction-heap pops in `ALLOCATE_MEMORY` (stale generations
    /// included — the lazy-invalidation overhead is part of the signal).
    pub dse_heap_pops: Counter,
    /// Undo-log trial rollbacks (random search / annealing proposals that
    /// were rejected or reset).
    pub dse_trial_rollbacks: Counter,
    /// Event-simulator runs completed.
    pub sim_runs: Counter,
    /// Semantic events across all runs (`Σ r`, extrapolated included).
    pub sim_events: Counter,
    /// Events the loops actually stepped (below `sim_events` when the
    /// steady-state fast-forward engaged).
    pub sim_events_processed: Counter,
    /// Runs where the steady-state detector extrapolated (one possible
    /// extrapolation per run).
    pub sim_fast_forwards: Counter,
    /// Whole hyperperiod rounds skipped by those extrapolations.
    pub sim_rounds_skipped: Counter,
}

/// The process-wide counter registry.
pub fn counters() -> &'static GlobalCounters {
    static GLOBAL: GlobalCounters = GlobalCounters {
        dse_greedy_steps: Counter::new(),
        dse_heap_pops: Counter::new(),
        dse_trial_rollbacks: Counter::new(),
        sim_runs: Counter::new(),
        sim_events: Counter::new(),
        sim_events_processed: Counter::new(),
        sim_fast_forwards: Counter::new(),
        sim_rounds_skipped: Counter::new(),
    };
    &GLOBAL
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let c = Counter::new();
        assert_eq!(c.get(), 0);
        c.incr();
        c.add(4);
        assert_eq!(c.get(), 5);
    }

    #[test]
    fn global_registry_is_shared_and_monotone() {
        let before = counters().sim_runs.get();
        counters().sim_runs.incr();
        assert!(counters().sim_runs.get() >= before + 1);
    }
}
