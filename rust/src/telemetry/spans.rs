//! Lock-free span ring buffers for the serving path.
//!
//! Each lane (pool worker or batcher shard) owns ONE [`SpanRing`] and is
//! its only writer; snapshots read concurrently through a seqlock
//! protocol. The write path is a handful of relaxed/release atomic stores
//! — no `Mutex`, no allocation — so recording a span cannot contend with
//! another lane, block a snapshot, or trip the
//! [`serving_path_locks`](crate::coordinator::Server::serving_path_locks)
//! tripwire. A snapshot that races a writer skips the slot being
//! rewritten (sequence validation) instead of tearing it.
//!
//! Spans are packed into two `u64` data words per slot: the start
//! timestamp (µs since the owning hub's epoch) and a packed
//! `kind | items | duration` word, so one record is exactly four atomic
//! stores (odd seal, two data words, even seal).

use std::sync::atomic::{fence, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// What a recorded span measured — one stage of the request lifecycle
/// through the sharded serving front
/// (admit → shard/batcher → mailbox or steal → worker/engine → reply).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanKind {
    /// Queue wait: earliest admission in the batch until the engine took it.
    Wait,
    /// Engine execution of one batch (simulated accelerator + numerics).
    Engine,
    /// Reply fan-out back to the submitters.
    Reply,
    /// Batch formation + mailbox hand-off on a batcher shard.
    Batch,
    /// A worker stealing a foreign mailbox batch (instant marker).
    Steal,
}

impl SpanKind {
    /// Every kind, in the stable exposition order.
    pub const ALL: [SpanKind; 5] = [
        SpanKind::Wait,
        SpanKind::Engine,
        SpanKind::Reply,
        SpanKind::Batch,
        SpanKind::Steal,
    ];

    /// Stable label used by every exposition format.
    pub fn label(self) -> &'static str {
        match self {
            SpanKind::Wait => "wait",
            SpanKind::Engine => "engine",
            SpanKind::Reply => "reply",
            SpanKind::Batch => "batch",
            SpanKind::Steal => "steal",
        }
    }

    fn code(self) -> u64 {
        match self {
            SpanKind::Wait => 0,
            SpanKind::Engine => 1,
            SpanKind::Reply => 2,
            SpanKind::Batch => 3,
            SpanKind::Steal => 4,
        }
    }

    fn from_code(code: u64) -> SpanKind {
        match code {
            0 => SpanKind::Wait,
            1 => SpanKind::Engine,
            2 => SpanKind::Reply,
            3 => SpanKind::Batch,
            _ => SpanKind::Steal,
        }
    }
}

/// One decoded span, as returned by snapshots.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Span {
    pub kind: SpanKind,
    /// Lane of the recording ring: a worker index, or
    /// [`SHARD_LANE_BASE`]` + shard` for batcher shards.
    pub lane: u32,
    /// Requests the span covered (clamped to 16 bits in storage).
    pub items: u32,
    /// Start, µs since the owning hub's epoch.
    pub start_us: u64,
    /// Duration in µs (clamped to 40 bits in storage — ~12 days).
    pub dur_us: u64,
}

impl Span {
    /// Is this span from a batcher-shard lane (vs a pool worker)?
    pub fn is_shard_lane(&self) -> bool {
        self.lane >= SHARD_LANE_BASE
    }
}

/// Shard lanes are offset by this base so worker and shard ids never
/// collide in one hub.
pub const SHARD_LANE_BASE: u32 = 1 << 16;

const DUR_BITS: u64 = 40;
const DUR_MASK: u64 = (1 << DUR_BITS) - 1;
const ITEM_BITS: u64 = 16;
const ITEM_MASK: u64 = (1 << ITEM_BITS) - 1;

fn pack(kind: SpanKind, items: u32, dur_us: u64) -> u64 {
    (kind.code() << (DUR_BITS + ITEM_BITS))
        | (u64::from(items).min(ITEM_MASK) << DUR_BITS)
        | dur_us.min(DUR_MASK)
}

fn unpack(word: u64) -> (SpanKind, u32, u64) {
    let kind = SpanKind::from_code(word >> (DUR_BITS + ITEM_BITS));
    let items = ((word >> DUR_BITS) & ITEM_MASK) as u32;
    (kind, items, word & DUR_MASK)
}

/// One seqlock-guarded slot: `seq` is odd while the writer is mid-update,
/// even (and non-zero) when the data words are consistent.
struct SpanSlot {
    seq: AtomicU64,
    start: AtomicU64,
    packed: AtomicU64,
}

/// Fixed-capacity single-writer span ring. The owning lane records;
/// any thread may snapshot concurrently.
pub struct SpanRing {
    lane: u32,
    mask: usize,
    slots: Box<[SpanSlot]>,
    /// Total records ever written (the ring index is `head & mask`).
    head: AtomicU64,
}

impl SpanRing {
    /// A ring for `lane` holding the last `capacity` spans (rounded up to
    /// a power of two, minimum 2).
    pub fn new(lane: u32, capacity: usize) -> SpanRing {
        let cap = capacity.next_power_of_two().max(2);
        let slots: Vec<SpanSlot> = (0..cap)
            .map(|_| SpanSlot {
                seq: AtomicU64::new(0),
                start: AtomicU64::new(0),
                packed: AtomicU64::new(0),
            })
            .collect();
        SpanRing { lane, mask: cap - 1, slots: slots.into_boxed_slice(), head: AtomicU64::new(0) }
    }

    pub fn lane(&self) -> u32 {
        self.lane
    }

    /// Total spans ever recorded (older ones may have been overwritten).
    pub fn recorded(&self) -> u64 {
        self.head.load(Ordering::Acquire)
    }

    /// Record one span. Single-writer: only the owning lane may call this.
    /// Lock-free and allocation-free — safe on the serving hot path.
    pub fn record(&self, kind: SpanKind, items: usize, start_us: u64, dur_us: u64) {
        let head = self.head.load(Ordering::Relaxed);
        let slot = &self.slots[head as usize & self.mask];
        let seq = slot.seq.load(Ordering::Relaxed);
        // seal odd, publish data, seal even (seqlock write protocol)
        slot.seq.store(seq.wrapping_add(1), Ordering::Relaxed);
        fence(Ordering::Release);
        slot.start.store(start_us, Ordering::Relaxed);
        slot.packed.store(pack(kind, items.min(u32::MAX as usize) as u32, dur_us), Ordering::Relaxed);
        slot.seq.store(seq.wrapping_add(2), Ordering::Release);
        self.head.store(head + 1, Ordering::Release);
    }

    /// Decode every completed span currently in the ring, oldest first.
    /// Lock-free: a slot the writer is concurrently rewriting is skipped
    /// (sequence validation), never torn.
    pub fn snapshot(&self) -> Vec<Span> {
        let head = self.head.load(Ordering::Acquire) as usize;
        let len = self.slots.len();
        let written = head.min(len);
        let first = if head > len { head & self.mask } else { 0 };
        let mut out = Vec::with_capacity(written);
        for k in 0..written {
            let slot = &self.slots[(first + k) & self.mask];
            let s1 = slot.seq.load(Ordering::Acquire);
            if s1 == 0 || s1 & 1 == 1 {
                continue; // never written, or mid-write
            }
            let start = slot.start.load(Ordering::Relaxed);
            let packed = slot.packed.load(Ordering::Relaxed);
            fence(Ordering::Acquire);
            if slot.seq.load(Ordering::Relaxed) != s1 {
                continue; // rewritten while we read
            }
            let (kind, items, dur_us) = unpack(packed);
            out.push(Span { kind, lane: self.lane, items, start_us: start, dur_us });
        }
        out
    }
}

/// A single lane's recording handle: the ring plus the hub epoch the
/// timestamps are relative to. Cloneable (workers hand one to the threads
/// they spawn).
#[derive(Clone)]
pub struct SpanScribe {
    ring: Arc<SpanRing>,
    epoch: Instant,
}

impl SpanScribe {
    pub(crate) fn new(ring: Arc<SpanRing>, epoch: Instant) -> SpanScribe {
        SpanScribe { ring, epoch }
    }

    /// µs of `t` since the hub epoch (0 for pre-epoch instants).
    pub fn us_of(&self, t: Instant) -> u64 {
        t.saturating_duration_since(self.epoch).as_micros() as u64
    }

    pub fn now_us(&self) -> u64 {
        self.us_of(Instant::now())
    }

    /// Record a span covering `start..end`.
    pub fn record_between(&self, kind: SpanKind, items: usize, start: Instant, end: Instant) {
        let s = self.us_of(start);
        let e = self.us_of(end);
        self.ring.record(kind, items, s, e.saturating_sub(s));
    }

    /// Record an instantaneous marker (duration 0) at now.
    pub fn mark(&self, kind: SpanKind, items: usize) {
        self.ring.record(kind, items, self.now_us(), 0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_roundtrips_and_clamps() {
        for kind in SpanKind::ALL {
            let (k, items, dur) = unpack(pack(kind, 37, 123_456));
            assert_eq!((k, items, dur), (kind, 37, 123_456));
        }
        // items clamp to 16 bits, durations to 40
        let (_, items, dur) = unpack(pack(SpanKind::Engine, u32::MAX, u64::MAX));
        assert_eq!(items, ITEM_MASK as u32);
        assert_eq!(dur, DUR_MASK);
    }

    #[test]
    fn ring_keeps_order_and_wraps() {
        let ring = SpanRing::new(7, 4);
        for i in 0..3 {
            ring.record(SpanKind::Engine, i as usize, i * 10, 5);
        }
        let spans = ring.snapshot();
        assert_eq!(spans.len(), 3);
        assert_eq!(spans[0].start_us, 0);
        assert_eq!(spans[2].start_us, 20);
        assert!(spans.iter().all(|s| s.lane == 7 && s.kind == SpanKind::Engine));
        // overflow the capacity: the oldest spans fall out, order holds
        for i in 3..9 {
            ring.record(SpanKind::Wait, i as usize, i * 10, 5);
        }
        let spans = ring.snapshot();
        assert_eq!(spans.len(), 4);
        assert_eq!(spans[0].start_us, 50);
        assert_eq!(spans[3].start_us, 80);
        assert_eq!(ring.recorded(), 9);
    }

    #[test]
    fn empty_ring_snapshots_empty() {
        assert!(SpanRing::new(0, 16).snapshot().is_empty());
    }

    #[test]
    fn concurrent_snapshots_never_tear() {
        // one writer, many readers: every decoded span must be internally
        // consistent (start == 1000*items, dur == items) — a torn read
        // would mix the two words of different records
        let ring = Arc::new(SpanRing::new(1, 8));
        let writer = {
            let ring = Arc::clone(&ring);
            std::thread::spawn(move || {
                for i in 1..20_000u64 {
                    ring.record(SpanKind::Engine, i as usize & 0xFF, (i & 0xFF) * 1000, i & 0xFF);
                }
            })
        };
        for _ in 0..200 {
            for s in ring.snapshot() {
                assert_eq!(s.start_us, u64::from(s.items) * 1000, "torn span: {s:?}");
                assert_eq!(s.dur_us, u64::from(s.items), "torn span: {s:?}");
            }
        }
        writer.join().unwrap();
    }

    #[test]
    fn scribe_timestamps_are_epoch_relative() {
        let ring = Arc::new(SpanRing::new(0, 8));
        let epoch = Instant::now();
        let scribe = SpanScribe::new(Arc::clone(&ring), epoch);
        // a pre-epoch instant saturates to 0 instead of panicking
        let t0 = epoch - std::time::Duration::from_secs(1);
        assert_eq!(scribe.us_of(t0), 0);
        scribe.record_between(SpanKind::Reply, 3, epoch, epoch + std::time::Duration::from_micros(250));
        scribe.mark(SpanKind::Steal, 2);
        let spans = ring.snapshot();
        assert_eq!(spans.len(), 2);
        assert_eq!(spans[0].kind, SpanKind::Reply);
        assert_eq!(spans[0].dur_us, 250);
        assert_eq!(spans[1].kind, SpanKind::Steal);
        assert_eq!(spans[1].dur_us, 0);
    }
}
