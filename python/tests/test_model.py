"""L2 model tests: shapes, quantization, and the streamed-vs-monolithic
numerics equivalence that proves the weight-streaming schedule is
value-preserving end-to-end."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels.ref import fake_quant

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def params():
    return model.init_params(seed=0)


def test_forward_shapes(params):
    x = jnp.zeros((2, 3, 32, 32))
    (logits,) = model.forward(params, x)
    assert logits.shape == (2, 10)


@pytest.mark.parametrize("batch", [1, 3, 8])
def test_streamed_equals_monolithic(params, batch):
    """The paper's core invariant, at full-model scope: running every conv
    and the classifier through the fragment-streamed kernel produces the
    same logits as plain matmuls."""
    x = jax.random.normal(jax.random.PRNGKey(batch), (batch, 3, 32, 32))
    (streamed,) = model.forward(params, x)
    (mono,) = model.forward_monolithic(params, x)
    np.testing.assert_allclose(streamed, mono, rtol=1e-5, atol=1e-4)


def test_weights_are_on_quant_grid(params):
    scale = 1.0 / 64
    for name, w in params.items():
        q = np.asarray(w) / scale
        np.testing.assert_allclose(q, np.round(q), atol=1e-5, err_msg=name)


def test_param_count_matches_rust_toy_cnn(params):
    """rust/src/models/toy.rs asserts 24_112 parameters; the artifacts must
    describe the same network."""
    count = sum(int(np.prod(w.shape)) for w in params.values())
    assert count == 432 + 4608 + 18432 + 640 == 24_112


def test_forward_is_deterministic(params):
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 3, 32, 32))
    (a,) = model.forward(params, x)
    (b,) = model.forward(params, x)
    np.testing.assert_array_equal(a, b)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_logits_finite_for_random_inputs(seed):
    params = model.init_params(seed=1)
    x = jax.random.uniform(jax.random.PRNGKey(seed), (1, 3, 32, 32), minval=-2, maxval=2)
    (logits,) = model.forward(params, x)
    assert np.isfinite(np.asarray(logits)).all()


def test_fake_quant_properties():
    x = jnp.linspace(-3, 3, 101)
    q = fake_quant(x, 8, scale=1.0 / 16)
    # idempotent
    np.testing.assert_allclose(fake_quant(q, 8, scale=1.0 / 16), q, atol=1e-7)
    # bounded error
    assert float(jnp.max(jnp.abs(q - jnp.clip(x, -8, 127 / 16)))) <= 1.0 / 32 + 1e-6
    # 32-bit passthrough
    np.testing.assert_array_equal(fake_quant(x, 32, 1.0), x)


def test_quantization_grid_size():
    x = jnp.linspace(-0.9, 0.9, 1001)
    q = np.unique(np.asarray(fake_quant(x, 4, scale=0.1)))
    assert len(q) <= 16, "4-bit grid has at most 16 levels"
