"""L2 inverted-residual block: streamed forward vs monolithic reference.

The paper's core numerics claim at model scope: fragment-streamed execution
computes exactly what a monolithic (all-weights-resident) execution computes
— only the schedule differs.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def params():
    return model.init_mobile_params(seed=0)


def test_streamed_equals_monolithic(params):
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 14, 14))
    (got,) = model.mobile_block_forward(params, x)
    (want,) = model.mobile_block_monolithic(params, x)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


def test_output_shape_preserved(params):
    x = jnp.zeros((4, 16, 14, 14))
    (y,) = model.mobile_block_forward(params, x)
    assert y.shape == (4, 16, 14, 14)


def test_residual_identity_at_zero_weights():
    """With all-zero weights the block must reduce to the quantized input."""
    params = {
        "expand": jnp.zeros((16, 96)),
        "dw": jnp.zeros((96, 3, 3)),
        "project": jnp.zeros((96, 16)),
    }
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 16, 14, 14))
    (y,) = model.mobile_block_forward(params, x)
    from compile.kernels.ref import fake_quant

    np.testing.assert_allclose(y, fake_quant(x, 8, 1.0 / 16), atol=1e-7)


def test_fragment_counts_do_not_change_values(params):
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 16, 14, 14))
    base_spec = model.MobileBlockSpec(
        n_frags_expand=1, n_frags_dw=1, n_frags_project=1
    )
    frag_spec = model.MobileBlockSpec(
        n_frags_expand=4, n_frags_dw=8, n_frags_project=6
    )
    (a,) = model.mobile_block_forward(params, x, spec=base_spec)
    (b,) = model.mobile_block_forward(params, x, spec=frag_spec)
    np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-5)


def test_lowering_to_hlo_text():
    """The artifact path: the block must lower to parseable HLO text."""
    import sys, os

    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    from compile import aot

    text = aot.lower_mobile_block(batch=2)
    assert "HloModule" in text
    assert len(text) > 1000
