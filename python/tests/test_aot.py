"""AOT lowering tests: the HLO-text artifacts must exist as parseable HLO
and must compute the same values as the eager model."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model

jax.config.update("jax_platform_name", "cpu")


def test_toy_cnn_hlo_text_structure():
    text = aot.lower_toy_cnn(batch=1)
    assert text.startswith("HloModule"), text[:80]
    assert "ENTRY" in text
    # weights are baked in: the entry computation takes exactly one
    # parameter, the image batch
    assert "entry_computation_layout={(f32[1,3,32,32]{3,2,1,0})" in text


def test_stream_matmul_hlo_text_structure():
    text = aot.lower_stream_matmul()
    assert text.startswith("HloModule")
    assert "f32[8,64]" in text and "f32[64,32]" in text


def test_lowered_matches_eager():
    """Round-trip through the HLO-text artifact path (via jax's own HLO
    runtime) and compare against the eager forward."""
    params = model.init_params(seed=0)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 3, 32, 32))

    def fn(inp):
        return model.forward(params, inp)

    compiled = jax.jit(fn).lower(jax.ShapeDtypeStruct(x.shape, x.dtype)).compile()
    (got,) = compiled(x)
    (want,) = model.forward(params, x)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_batch_variants_agree_on_shared_prefix():
    """The b=8 artifact padded with zeros must agree with the b=1 artifact
    on the first sample — the coordinator relies on this when padding
    partial batches."""
    params = model.init_params(seed=0)
    x1 = jax.random.normal(jax.random.PRNGKey(2), (1, 3, 32, 32))
    x8 = jnp.concatenate([x1, jnp.zeros((7, 3, 32, 32))], axis=0)
    (l1,) = model.forward(params, x1)
    (l8,) = model.forward(params, x8)
    np.testing.assert_allclose(l8[:1], l1, rtol=1e-5, atol=1e-5)
