"""L1 depthwise kernel correctness: stream_depthwise vs the lax oracle.

Hypothesis sweeps shapes, strides, padding and fragment counts; the kernel
must match ``ref_depthwise`` for every configuration, and fragmentation must
be value-preserving (the paper's schedule-not-values invariant).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import stream_depthwise
from compile.kernels.ref import ref_depthwise

jax.config.update("jax_platform_name", "cpu")


def divisors(x):
    return [d for d in range(1, x + 1) if x % d == 0]


@st.composite
def dw_case(draw):
    b = draw(st.integers(1, 3))
    c = draw(st.sampled_from([2, 4, 8, 12, 16]))
    k = draw(st.sampled_from([1, 3, 5]))
    stride = draw(st.sampled_from([1, 2]))
    pad = draw(st.integers(0, k // 2))
    # input must produce a non-empty output map
    h = draw(st.integers(max(k, 4), 14))
    w = draw(st.integers(max(k, 4), 14))
    n_frags = draw(st.sampled_from(divisors(c)))
    seed = draw(st.integers(0, 2**31 - 1))
    return b, c, k, stride, pad, h, w, n_frags, seed


@settings(max_examples=40, deadline=None)
@given(dw_case())
def test_stream_depthwise_matches_ref(case):
    b, c, k, stride, pad, h, w, n_frags, seed = case
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (b, c, h, w), dtype=jnp.float32)
    wt = jax.random.normal(kw, (c, k, k), dtype=jnp.float32)
    got = stream_depthwise(x, wt, stride=stride, pad=pad, n_frags=n_frags)
    want = ref_depthwise(x, wt, stride=stride, pad=pad)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("n_frags", [1, 2, 4, 8, 16])
def test_fragmentation_is_value_preserving(n_frags):
    kx, kw = jax.random.split(jax.random.PRNGKey(11))
    x = jax.random.normal(kx, (2, 16, 10, 10), dtype=jnp.float32)
    w = jax.random.normal(kw, (16, 3, 3), dtype=jnp.float32)
    base = stream_depthwise(x, w, stride=1, pad=1, n_frags=1)
    frag = stream_depthwise(x, w, stride=1, pad=1, n_frags=n_frags)
    np.testing.assert_allclose(frag, base, rtol=1e-6, atol=1e-6)


def test_integer_values_are_exact():
    rng = np.random.RandomState(5)
    x = jnp.asarray(rng.randint(-8, 8, size=(1, 8, 7, 7)).astype(np.float32))
    w = jnp.asarray(rng.randint(-8, 8, size=(8, 3, 3)).astype(np.float32))
    got = np.asarray(stream_depthwise(x, w, stride=1, pad=1, n_frags=4))
    want = np.asarray(ref_depthwise(x, w, stride=1, pad=1))
    assert (got == want).all()


def test_mobilenet_like_shape():
    """A real MobileNetV2 depthwise stage: 32ch 112x112 stride-1 k3."""
    kx, kw = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(kx, (1, 32, 28, 28), dtype=jnp.float32)  # scaled-down spatial
    w = jax.random.normal(kw, (32, 3, 3), dtype=jnp.float32)
    got = stream_depthwise(x, w, stride=1, pad=1, n_frags=8)
    assert got.shape == (1, 32, 28, 28)
    want = ref_depthwise(x, w, stride=1, pad=1)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


def test_stride2_downsample_shape():
    x = jnp.ones((1, 4, 9, 9))
    w = jnp.ones((4, 3, 3))
    out = stream_depthwise(x, w, stride=2, pad=1, n_frags=2)
    assert out.shape == (1, 4, 5, 5)
    # interior output pixels see all 9 taps of an all-ones input
    assert float(out[0, 0, 2, 2]) == 9.0


def test_bad_fragment_count_raises():
    with pytest.raises(ValueError, match="must divide"):
        stream_depthwise(jnp.zeros((1, 6, 8, 8)), jnp.zeros((6, 3, 3)), n_frags=4)


def test_filter_shape_mismatch_raises():
    with pytest.raises(ValueError, match="mismatch"):
        stream_depthwise(jnp.zeros((1, 6, 8, 8)), jnp.zeros((4, 3, 3)))
