"""L1 kernel correctness: stream_matmul vs the pure-jnp oracle.

Hypothesis sweeps shapes, fragment counts and value ranges; the kernel must
match ``ref_matmul`` to f32 accumulation tolerance for every configuration.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import stream_matmul, vmem_footprint_bytes
from compile.kernels.ref import ref_matmul

jax.config.update("jax_platform_name", "cpu")


def divisors(x):
    return [d for d in range(1, x + 1) if x % d == 0]


@st.composite
def matmul_case(draw):
    m = draw(st.integers(1, 24))
    k = draw(st.sampled_from([4, 8, 12, 16, 32, 48, 64]))
    n = draw(st.integers(1, 24))
    n_frags = draw(st.sampled_from(divisors(k)))
    seed = draw(st.integers(0, 2**31 - 1))
    return m, k, n, n_frags, seed


@settings(max_examples=60, deadline=None)
@given(matmul_case())
def test_stream_matmul_matches_ref(case):
    m, k, n, n_frags, seed = case
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (m, k), dtype=jnp.float32)
    w = jax.random.normal(kw, (k, n), dtype=jnp.float32)
    got = stream_matmul(x, w, n_frags=n_frags)
    want = ref_matmul(x, w)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5 * k)


@pytest.mark.parametrize("n_frags", [1, 2, 4, 8, 16])
def test_fragment_count_is_value_preserving(n_frags):
    """The paper's key numerics invariant: fragmentation must not change
    the result (only the schedule)."""
    kx, kw = jax.random.split(jax.random.PRNGKey(7))
    x = jax.random.normal(kx, (16, 64), dtype=jnp.float32)
    w = jax.random.normal(kw, (64, 32), dtype=jnp.float32)
    base = stream_matmul(x, w, n_frags=1)
    frag = stream_matmul(x, w, n_frags=n_frags)
    np.testing.assert_allclose(frag, base, rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize(
    "dtype", [jnp.float32, jnp.bfloat16, jnp.int8]
)
def test_input_dtypes_are_upcast(dtype):
    x = (jnp.arange(8 * 16).reshape(8, 16) % 5 - 2).astype(dtype)
    w = (jnp.arange(16 * 4).reshape(16, 4) % 7 - 3).astype(dtype)
    got = stream_matmul(x, w, n_frags=4)
    assert got.dtype == jnp.float32
    want = ref_matmul(x.astype(jnp.float32), w.astype(jnp.float32))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-3)


def test_integer_values_are_exact():
    """Quantized weights are small integers on an f32 carrier — products
    must be bit-exact regardless of fragmentation."""
    rng = np.random.RandomState(3)
    x = rng.randint(-8, 8, size=(12, 36)).astype(np.float32)
    w = rng.randint(-8, 8, size=(36, 10)).astype(np.float32)
    for n_frags in (1, 2, 3, 6, 9):
        got = np.asarray(stream_matmul(jnp.asarray(x), jnp.asarray(w), n_frags=n_frags))
        assert (got == x @ w).all(), f"n_frags={n_frags} not integer-exact"


def test_bad_fragment_count_raises():
    x = jnp.zeros((4, 10))
    w = jnp.zeros((10, 4))
    with pytest.raises(ValueError, match="must divide"):
        stream_matmul(x, w, n_frags=3)


def test_shape_mismatch_raises():
    with pytest.raises(ValueError, match="mismatch"):
        stream_matmul(jnp.zeros((4, 8)), jnp.zeros((9, 4)))


def test_vmem_footprint_shrinks_with_fragments():
    """More fragments -> smaller per-step working set (the whole point of
    streaming): the weight-fragment term scales as 1/n."""
    sizes = [vmem_footprint_bytes(128, 1024, 128, n) for n in (1, 2, 4, 8)]
    assert sizes == sorted(sizes, reverse=True)
    # resident output block is the floor
    assert sizes[-1] >= 4 * 128 * 128
