"""AOT lowering: JAX model -> HLO text artifacts for the Rust runtime.

Interchange format is HLO **text**, not ``HloModuleProto.serialize()``:
jax >= 0.5 emits protos with 64-bit instruction ids which the ``xla`` crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids, so text round-trips cleanly. See /opt/xla-example/README.md.

Run once via ``make artifacts``; the Rust binary is self-contained after.

Artifacts written:
  toy_cnn_b1.hlo.txt / toy_cnn_b8.hlo.txt
      quantized toy-CNN forward (weights baked as constants; input: image
      batch) — the serving path's numerics.
  stream_matmul.hlo.txt
      the bare L1 kernel at (8,64)@(64,32), n_frags=4 — used by the Rust
      runtime round-trip integration test.
"""

import argparse
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from compile import model  # noqa: E402
from compile.kernels import stream_matmul  # noqa: E402


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_toy_cnn(batch: int, seed: int = 0) -> str:
    params = model.init_params(seed)

    def fn(x):
        return model.forward(params, x)

    spec = jax.ShapeDtypeStruct((batch, *model.SPEC.input_shape), jnp.float32)
    return to_hlo_text(jax.jit(fn).lower(spec))


def lower_mobile_block(batch: int, seed: int = 0) -> str:
    params = model.init_mobile_params(seed)

    def fn(x):
        return model.mobile_block_forward(params, x)

    spec_shape = (batch, model.MOBILE_SPEC.c_in, model.MOBILE_SPEC.spatial,
                  model.MOBILE_SPEC.spatial)
    spec = jax.ShapeDtypeStruct(spec_shape, jnp.float32)
    return to_hlo_text(jax.jit(fn).lower(spec))


def lower_stream_matmul(m=8, k=64, n=32, n_frags=4) -> str:
    def fn(x, w):
        return (stream_matmul(x, w, n_frags=n_frags),)

    xs = jax.ShapeDtypeStruct((m, k), jnp.float32)
    ws = jax.ShapeDtypeStruct((k, n), jnp.float32)
    return to_hlo_text(jax.jit(fn).lower(xs, ws))


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    artifacts = {
        "toy_cnn_b1.hlo.txt": lambda: lower_toy_cnn(1, args.seed),
        "toy_cnn_b8.hlo.txt": lambda: lower_toy_cnn(8, args.seed),
        "stream_matmul.hlo.txt": lower_stream_matmul,
        "mobile_block_b4.hlo.txt": lambda: lower_mobile_block(4, args.seed),
    }
    for name, build in artifacts.items():
        path = os.path.join(args.out_dir, name)
        text = build()
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {len(text):>9} chars -> {path}")


if __name__ == "__main__":
    main()
