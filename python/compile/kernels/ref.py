"""Pure-jnp correctness oracles for the Pallas kernels and the L2 model.

These are the ground truth the pytest suite checks everything against; they
deliberately use only `jnp` primitives (no pallas, no custom calls).
"""

import jax.numpy as jnp


def ref_matmul(x, w):
    """Oracle for kernels.stream_matmul."""
    return jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32))


def ref_conv2d(x, w, stride=1, pad=0):
    """NCHW direct convolution oracle (dense, groups=1).

    Args:
      x: (B, C, H, W) activations.
      w: (F, C, K, K) filters.
    """
    lhs = x.astype(jnp.float32)
    rhs = w.astype(jnp.float32)
    import jax

    return jax.lax.conv_general_dilated(
        lhs,
        rhs,
        window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )


def fake_quant(x, bits, scale):
    """Uniform symmetric fake-quantization to `bits` (f32 carrier):
    round(clip(x/scale)) * scale on the signed integer grid."""
    if bits >= 32:
        return x
    qmax = 2.0 ** (bits - 1) - 1
    q = jnp.clip(jnp.round(x / scale), -qmax - 1, qmax)
    return q * scale


def ref_im2col(x, k, stride=1, pad=0):
    """im2col for NCHW input: returns (B*Ho*Wo, C*k*k) patches."""
    b, c, h, w = x.shape
    xp = jnp.pad(x, ((0, 0), (0, 0), (pad, pad), (pad, pad)))
    ho = (h + 2 * pad - k) // stride + 1
    wo = (w + 2 * pad - k) // stride + 1
    idx_h = jnp.arange(ho) * stride
    idx_w = jnp.arange(wo) * stride
    # gather k x k windows
    patches = jnp.stack(
        [
            xp[:, :, idx_h[:, None] + dh, idx_w[None, :] + dw]
            for dh in range(k)
            for dw in range(k)
        ],
        axis=2,
    )  # (B, C, k*k, Ho, Wo)
    patches = patches.reshape(b, c * k * k, ho, wo)
    patches = patches.transpose(0, 2, 3, 1).reshape(b * ho * wo, c * k * k)
    return patches, ho, wo


def ref_depthwise(x, w, stride=1, pad=0):
    """Depthwise convolution oracle for kernels.stream_depthwise.

    Args:
      x: (B, C, H, W) activations.
      w: (C, K, K) one filter per channel.
    """
    import jax

    c = x.shape[1]
    rhs = w[:, None, :, :].astype(jnp.float32)  # (C, 1, K, K) == OIHW, I=1
    return jax.lax.conv_general_dilated(
        x.astype(jnp.float32),
        rhs,
        window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
        feature_group_count=c,
    )
