"""Layer-1 Pallas kernels and their pure-jnp oracles."""

from .stream_matmul import stream_matmul, vmem_footprint_bytes  # noqa: F401
from .depthwise import stream_depthwise  # noqa: F401
from . import ref  # noqa: F401
