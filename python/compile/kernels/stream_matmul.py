"""Layer-1 Pallas kernel: weight-streaming matmul.

The TPU-idiom analogue of AutoWS's fragmented weights memory (paper Fig. 3):
the weight matrix is partitioned along its reduction dimension into `n`
fragments. The Pallas grid walks the fragment axis; at each step the
`BlockSpec` stages one fragment HBM->VMEM (the paper's off-chip -> shared
buffer DMA burst, double-buffered by the hardware against the previous
step's MXU work, i.e. the clk_dma/clk_comp overlap) and accumulates its
partial product into the resident output block (the paper's Read-After-Write
ordering: a fragment's contribution lands only once its block is resident).

DESIGN.md §Hardware-Adaptation documents the full FPGA->TPU mapping.

Everything here runs with ``interpret=True``: real TPU lowering emits a
Mosaic custom-call that the CPU PJRT client cannot execute; interpret mode
lowers to plain HLO so the AOT artifacts run anywhere.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, o_ref, *, n_frags):
    """One grid step: accumulate x_frag @ w_frag into the output block."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # MXU-shaped partial product; f32 accumulation.
    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def stream_matmul(x, w, *, n_frags=4):
    """``x @ w`` with ``w`` streamed in ``n_frags`` fragments along K.

    Args:
      x: ``(M, K)`` activations (resident, the paper's on-chip stream).
      w: ``(K, N)`` weights (streamed fragment-by-fragment).
      n_frags: number of weight fragments ``n`` (paper Eq. 2). Must divide K.

    Returns:
      ``(M, N)`` float32 product, numerically equal (up to accumulation
      order) to ``x @ w``.
    """
    m, k = x.shape
    k2, n = w.shape
    if k != k2:
        raise ValueError(f"inner dims mismatch: {x.shape} @ {w.shape}")
    if k % n_frags != 0:
        raise ValueError(f"n_frags={n_frags} must divide K={k}")
    frag = k // n_frags

    return pl.pallas_call(
        functools.partial(_kernel, n_frags=n_frags),
        grid=(n_frags,),
        in_specs=[
            # activations: the K-slice matching the current fragment
            pl.BlockSpec((m, frag), lambda i: (0, i)),
            # weights: fragment i staged HBM->VMEM (the DMA burst)
            pl.BlockSpec((frag, n), lambda i: (i, 0)),
        ],
        # output block resident across all grid steps (accumulator)
        out_specs=pl.BlockSpec((m, n), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32), w.astype(jnp.float32))


def vmem_footprint_bytes(m, k, n, n_frags, dtype_bytes=4):
    """Estimated VMEM working set of one grid step (for the §Perf table):
    x-slice + one weight fragment + the resident output block."""
    frag = k // n_frags
    return dtype_bytes * (m * frag + frag * n + m * n)
