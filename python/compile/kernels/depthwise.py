"""Layer-1 Pallas kernel: weight-streaming depthwise convolution.

MobileNetV2's depthwise layers are the grouped-conv extreme (groups == C,
paper §III-B generalization with ``c_per_group = 1``). The weight tensor is
tiny per channel (k*k values) but the channel count is large, so AutoWS
fragments it along the *channel* axis: each grid step stages one channel
block's filters HBM->VMEM (the paper's off-chip fragment DMA) and convolves
the matching channel slice of the input.

Stride is implemented by computing the dense (stride-1) output and
subsampling — keeps the kernel's inner loop a pure shift-and-MAC over the
static k*k taps, the same structure as the FPGA CE's sliding window + PE
array (paper Fig. 2).

``interpret=True`` everywhere (see stream_matmul.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, o_ref, *, k, stride, ho, wo):
    """One grid step: depthwise-convolve one channel fragment.

    x_ref: (B, C_blk, H_pad, W_pad)  padded input channel block
    w_ref: (C_blk, k, k)             this block's filters (the DMA'd fragment)
    o_ref: (B, C_blk, Ho, Wo)        output channel block
    """
    x = x_ref[...]
    w = w_ref[...]
    span_h = 1 + stride * (ho - 1)
    span_w = 1 + stride * (wo - 1)
    acc = jnp.zeros(o_ref.shape, dtype=jnp.float32)
    # static k*k tap loop — unrolled at trace time, like the CE's k_p unroll
    for dh in range(k):
        for dw in range(k):
            sl = x[:, :, dh : dh + span_h : stride, dw : dw + span_w : stride]
            acc += sl * w[:, dh, dw][None, :, None, None]
    o_ref[...] = acc


def stream_depthwise(x, w, *, stride=1, pad=0, n_frags=1):
    """Depthwise conv with channel-fragmented weight streaming.

    Args:
      x: ``(B, C, H, W)`` activations.
      w: ``(C, K, K)`` one filter per channel.
      stride: spatial stride.
      pad: symmetric zero padding.
      n_frags: channel fragments ``n`` (paper Eq. 2). Must divide C.

    Returns:
      ``(B, C, Ho, Wo)`` float32 output.
    """
    b, c, h, wd = x.shape
    c2, k, k2 = w.shape
    if c != c2 or k != k2:
        raise ValueError(f"filter shape mismatch: x {x.shape}, w {w.shape}")
    if c % n_frags != 0:
        raise ValueError(f"n_frags={n_frags} must divide C={c}")
    ho = (h + 2 * pad - k) // stride + 1
    wo = (wd + 2 * pad - k) // stride + 1
    if ho <= 0 or wo <= 0:
        raise ValueError(f"empty output map for input {x.shape}, k={k}, stride={stride}")
    c_blk = c // n_frags

    xp = jnp.pad(x.astype(jnp.float32), ((0, 0), (0, 0), (pad, pad), (pad, pad)))
    hp, wp = h + 2 * pad, wd + 2 * pad

    return pl.pallas_call(
        functools.partial(_kernel, k=k, stride=stride, ho=ho, wo=wo),
        grid=(n_frags,),
        in_specs=[
            # input: the channel slice matching the current fragment
            pl.BlockSpec((b, c_blk, hp, wp), lambda i: (0, i, 0, 0)),
            # weights: fragment i staged HBM->VMEM (the DMA burst)
            pl.BlockSpec((c_blk, k, k), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((b, c_blk, ho, wo), lambda i: (0, i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, c, ho, wo), jnp.float32),
        interpret=True,
    )(xp, w.astype(jnp.float32))
