"""Layer-2 JAX model: the quantized toy CNN served by the Rust coordinator.

Architecture MUST mirror ``rust/src/models/toy.rs`` (`toy_cnn`): the Rust
side derives the accelerator schedule from the same network the artifacts
compute. Convolutions are lowered to im2col + the Layer-1 weight-streaming
Pallas matmul kernel, so every conv's weight traffic follows the paper's
fragment schedule. Weights and activations are fake-quantized to W8A8 on an
f32 carrier — integer-exact arithmetic without integer dtypes, matching the
bit-accurate behaviour of the FPGA datapath.

Build-time only: `aot.py` lowers `forward` to HLO text once; Python never
runs on the request path.
"""

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .kernels import stream_matmul
from .kernels.ref import fake_quant, ref_im2col


@dataclass(frozen=True)
class ToyCnnSpec:
    """Keep in sync with rust/src/models/toy.rs."""

    input_shape: tuple = (3, 32, 32)
    # (name, c_in, c_out, kernel, stride, pad)
    convs: tuple = (
        ("conv1", 3, 16, 3, 1, 1),
        ("conv2", 16, 32, 3, 2, 1),
        ("conv3", 32, 64, 3, 2, 1),
    )
    fc: tuple = ("fc", 64, 10)
    w_bits: int = 8
    a_bits: int = 8
    # fragments for the streamed layers (paper Eq. 2 `n`); conv3 and fc are
    # the "evicted" layers in the reference schedule.
    n_frags: dict = None

    def frags_for(self, name):
        default = {"conv1": 1, "conv2": 1, "conv3": 4, "fc": 2}
        table = self.n_frags or default
        return table.get(name, 1)


SPEC = ToyCnnSpec()


def init_params(seed=0, spec=SPEC):
    """He-init conv/fc weights, fake-quantized to the weight grid."""
    keys = jax.random.split(jax.random.PRNGKey(seed), len(spec.convs) + 1)
    params = {}
    for key, (name, c_in, c_out, k, _, _) in zip(keys[:-1], spec.convs):
        fan_in = c_in * k * k
        w = jax.random.normal(key, (c_out, c_in, k, k)) * (2.0 / fan_in) ** 0.5
        params[name] = fake_quant(w, spec.w_bits, scale=1.0 / 64)
    name, c_in, c_out = spec.fc
    w = jax.random.normal(keys[-1], (c_in, c_out)) * (2.0 / c_in) ** 0.5
    params[name] = fake_quant(w, spec.w_bits, scale=1.0 / 64)
    return params


def _quant_act(x, spec):
    return fake_quant(x, spec.a_bits, scale=1.0 / 16)


def conv2d_streamed(x, w, stride, pad, n_frags):
    """Convolution as im2col + the L1 weight-streaming kernel.

    The weight matrix (C*k*k, F) is fragmented along its reduction dim —
    the same axis the paper fragments `M_dep` on (Eq. 1: depth = f_t c_t
    k_t²).
    """
    f, c, k, _ = w.shape
    patches, ho, wo = ref_im2col(x, k, stride, pad)
    wmat = w.reshape(f, c * k * k).T  # (C*k*k, F)
    depth = c * k * k
    # fragments must divide the reduction depth; fall back to 1 otherwise
    n = n_frags if depth % n_frags == 0 else 1
    y = stream_matmul(patches, wmat, n_frags=n)  # (B*Ho*Wo, F)
    b = x.shape[0]
    return y.reshape(b, ho, wo, f).transpose(0, 3, 1, 2)


def forward(params, x, spec=SPEC):
    """Quantized forward pass: logits for a (B, 3, 32, 32) input batch."""
    h = _quant_act(x, spec)
    for name, _, _, k, stride, pad in spec.convs:
        h = conv2d_streamed(h, params[name], stride, pad, spec.frags_for(name))
        h = jax.nn.relu(h)
        h = _quant_act(h, spec)
    # global average pool
    h = h.mean(axis=(2, 3))
    # classifier (streamed matmul as well)
    logits = stream_matmul(h, params[spec.fc[0]], n_frags=spec.frags_for(spec.fc[0]))
    return (logits,)


def forward_monolithic(params, x, spec=SPEC):
    """Reference forward with plain (non-streamed) matmuls — the numerics
    oracle proving the fragment schedule is value-preserving."""
    h = _quant_act(x, spec)
    for name, _, _, k, stride, pad in spec.convs:
        w = params[name]
        f, c, kk, _ = w.shape
        patches, ho, wo = ref_im2col(h, kk, stride, pad)
        y = patches @ w.reshape(f, c * kk * kk).T.astype(jnp.float32)
        b = h.shape[0]
        h = y.reshape(b, ho, wo, f).transpose(0, 3, 1, 2)
        h = jax.nn.relu(h)
        h = _quant_act(h, spec)
    h = h.mean(axis=(2, 3))
    return (h @ params[spec.fc[0]].astype(jnp.float32),)


# --- MobileNetV2-style inverted-residual block -----------------------------
#
# The second L2 model: pointwise-expand -> depthwise -> pointwise-project
# with a residual add, every weight tensor streamed through an L1 kernel
# (matmuls fragment the reduction depth, the depthwise kernel fragments the
# channel axis). Exercises the grouped-conv generalization of paper §III-B.


@dataclass(frozen=True)
class MobileBlockSpec:
    """One inverted-residual block (stride 1 => residual connection)."""

    c_in: int = 16
    expand: int = 6
    spatial: int = 14
    w_bits: int = 8
    a_bits: int = 8
    # fragment counts for the three weight tensors
    n_frags_expand: int = 2
    n_frags_dw: int = 4
    n_frags_project: int = 4

    @property
    def c_mid(self):
        return self.c_in * self.expand


MOBILE_SPEC = MobileBlockSpec()


def init_mobile_params(seed=0, spec=MOBILE_SPEC):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    c, m = spec.c_in, spec.c_mid
    params = {
        # pointwise conv == matmul over channels: store as (C_in, C_mid)
        "expand": fake_quant(
            jax.random.normal(k1, (c, m)) * (2.0 / c) ** 0.5, spec.w_bits, 1.0 / 64
        ),
        "dw": fake_quant(
            jax.random.normal(k2, (m, 3, 3)) * (2.0 / 9) ** 0.5, spec.w_bits, 1.0 / 64
        ),
        "project": fake_quant(
            jax.random.normal(k3, (m, c)) * (2.0 / m) ** 0.5, spec.w_bits, 1.0 / 64
        ),
    }
    return params


def mobile_block_forward(params, x, spec=MOBILE_SPEC):
    """Streamed inverted-residual block: (B, C, H, W) -> (B, C, H, W)."""
    from .kernels import stream_depthwise

    b, c, h, w = x.shape
    xq = fake_quant(x, spec.a_bits, 1.0 / 16)

    # pointwise expand: channels-last matmul via the streaming kernel
    t = xq.transpose(0, 2, 3, 1).reshape(b * h * w, c)
    t = stream_matmul(t, params["expand"], n_frags=spec.n_frags_expand)
    t = jax.nn.relu6(t)
    t = fake_quant(t, spec.a_bits, 1.0 / 16)
    t = t.reshape(b, h, w, spec.c_mid).transpose(0, 3, 1, 2)

    # depthwise 3x3, channel-fragmented streaming
    t = stream_depthwise(t, params["dw"], stride=1, pad=1, n_frags=spec.n_frags_dw)
    t = jax.nn.relu6(t)
    t = fake_quant(t, spec.a_bits, 1.0 / 16)

    # pointwise project (linear, no activation) + residual
    t = t.transpose(0, 2, 3, 1).reshape(b * h * w, spec.c_mid)
    t = stream_matmul(t, params["project"], n_frags=spec.n_frags_project)
    t = t.reshape(b, h, w, c).transpose(0, 3, 1, 2)
    return (xq + t,)


def mobile_block_monolithic(params, x, spec=MOBILE_SPEC):
    """Plain-jnp reference of the same block (no streaming kernels)."""
    from .kernels.ref import ref_depthwise

    b, c, h, w = x.shape
    xq = fake_quant(x, spec.a_bits, 1.0 / 16)

    t = xq.transpose(0, 2, 3, 1).reshape(b * h * w, c)
    t = t @ params["expand"].astype(jnp.float32)
    t = jax.nn.relu6(t)
    t = fake_quant(t, spec.a_bits, 1.0 / 16)
    t = t.reshape(b, h, w, spec.c_mid).transpose(0, 3, 1, 2)

    t = ref_depthwise(t, params["dw"], stride=1, pad=1)
    t = jax.nn.relu6(t)
    t = fake_quant(t, spec.a_bits, 1.0 / 16)

    t = t.transpose(0, 2, 3, 1).reshape(b * h * w, spec.c_mid)
    t = t @ params["project"].astype(jnp.float32)
    t = t.reshape(b, h, w, c).transpose(0, 3, 1, 2)
    return (xq + t,)
