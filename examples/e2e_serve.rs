//! END-TO-END DRIVER (DESIGN.md experiment "E2E"): serve batched inference
//! requests on a real (toy) quantized CNN through the full stack —
//!
//!   L1 Pallas weight-streaming kernel (inside the AOT artifact)
//!   L2 JAX quantized forward, lowered once to HLO text
//!   L3 Rust: DSE schedule + PJRT numerics + coordinator batching
//!
//! — proving all three layers compose. Reports latency/throughput; the run
//! is recorded in EXPERIMENTS.md.
//!
//! ```sh
//! make artifacts && cargo run --release --example e2e_serve
//! ```

use std::time::{Duration, Instant};

use autows::coordinator::{BatchPolicy, PjrtEngine, Server};
use autows::device::Device;
use autows::dse::{self, DseConfig};
use autows::ir::Quant;
use autows::models;
use autows::runtime::Runtime;
use autows::schedule::BurstSchedule;

fn main() -> anyhow::Result<()> {
    let artifact = format!("{}/artifacts/toy_cnn_b8.hlo.txt", env!("CARGO_MANIFEST_DIR"));
    anyhow::ensure!(
        std::path::Path::new(&artifact).exists(),
        "{artifact} missing — run `make artifacts` first"
    );

    // ---- L3 schedule: the accelerator design for the same network ----
    let net = models::toy_cnn(Quant::W8A8);
    let dev = Device::zcu102();
    let plan = dse::run(&net, &dev, &DseConfig::default()).expect("toy CNN fits zcu102");
    let sched = BurstSchedule::from_design(&plan.design, &dev, 8);
    println!(
        "accelerator plan on {}: {:.0} fps, {} streaming layers (balanced={})",
        dev.name,
        plan.throughput,
        sched.entries.len(),
        sched.balanced()
    );

    // ---- serving loop: PJRT numerics + simulated accelerator clock ----
    let design = plan.design;
    let server = Server::start_with(
        move || {
            let rt = Runtime::cpu()?;
            println!("PJRT platform: {}", rt.platform());
            let model = rt.load_hlo_text(&artifact)?;
            Ok(Box::new(PjrtEngine::new(model, design, dev, (3, 32, 32), 8)) as _)
        },
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) },
    )?;

    const REQUESTS: usize = 512;
    let t0 = Instant::now();
    let receivers: Vec<_> = (0..REQUESTS)
        .map(|i| {
            // deterministic synthetic "image"
            let input: Vec<f32> =
                (0..3 * 32 * 32).map(|j| ((i * 131 + j * 7) % 255) as f32 / 255.0 - 0.5).collect();
            server.submit(input).unwrap()
        })
        .collect();
    let mut predictions = vec![0usize; 10];
    for rx in receivers {
        let resp = rx.recv()??;
        let argmax = resp
            .output
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        predictions[argmax] += 1;
    }
    let wall = t0.elapsed();

    let m = server.metrics();
    println!(
        "\n{REQUESTS} requests in {:.1} ms wall: {:.0} req/s, \
         p50 {:.2} ms, p99 {:.2} ms, mean batch {:.1}",
        wall.as_secs_f64() * 1e3,
        REQUESTS as f64 / wall.as_secs_f64(),
        m.p50_ms,
        m.p99_ms,
        m.mean_batch
    );
    println!(
        "simulated accelerator time: {:.2} ms total ({:.3} ms per batch)",
        m.sim_accel_s * 1e3,
        m.sim_accel_s * 1e3 / m.batches as f64
    );
    println!("prediction histogram (10 classes): {predictions:?}");
    server.shutdown();
    Ok(())
}
