//! END-TO-END DRIVER (DESIGN.md experiment "E2E"): serve batched inference
//! requests on a real (toy) quantized CNN through the full stack —
//!
//!   L1 Pallas weight-streaming kernel (inside the AOT artifact)
//!   L2 JAX quantized forward, lowered once to HLO text
//!   L3 Rust: `autows::pipeline` DSE + schedule + PJRT numerics +
//!      coordinator batching
//!
//! — proving all three layers compose. Reports latency/throughput; the run
//! is recorded in EXPERIMENTS.md.
//!
//! ```sh
//! make artifacts && cargo run --release --example e2e_serve
//! ```

use std::time::{Duration, Instant};

use autows::coordinator::{BatchPolicy, ServerOptions};
use autows::dse::DseConfig;
use autows::ir::Quant;
use autows::pipeline::{Deployment, EngineSpec};
use autows::Error;

fn main() -> Result<(), Error> {
    let artifact = format!("{}/artifacts/toy_cnn_b8.hlo.txt", env!("CARGO_MANIFEST_DIR"));
    if !std::path::Path::new(&artifact).exists() {
        return Err(Error::Serve(format!("{artifact} missing — run `make artifacts` first")));
    }

    // ---- L3 pipeline: model → DSE → burst schedule → serving engine ----
    let scheduled = Deployment::for_model("toy")
        .quant(Quant::W8A8)
        .on_device("zcu102")?
        .explore(&DseConfig::default())?
        .schedule_for_batch(8)
        .with_engine(EngineSpec::Pjrt { artifact, input_shape: (3, 32, 32), artifact_batch: 8 });
    println!(
        "accelerator plan on {}: {:.0} fps, {} streaming layers (balanced={})",
        scheduled.device().name,
        scheduled.result().throughput,
        scheduled.burst_schedule().entries.len(),
        scheduled.burst_schedule().balanced()
    );

    // ---- serving loop: PJRT numerics + simulated accelerator clock ----
    let server = scheduled.serve(
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) },
        ServerOptions::default(),
    )?;

    const REQUESTS: usize = 512;
    let t0 = Instant::now();
    let receivers: Vec<_> = (0..REQUESTS)
        .map(|i| {
            // deterministic synthetic "image"
            let input: Vec<f32> =
                (0..3 * 32 * 32).map(|j| ((i * 131 + j * 7) % 255) as f32 / 255.0 - 0.5).collect();
            server.submit(input).expect("submit")
        })
        .collect();
    let mut predictions = vec![0usize; 10];
    for rx in receivers {
        let resp = rx
            .recv()
            .map_err(|_| Error::Serve("coordinator dropped request".into()))??;
        let argmax = resp
            .output
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        predictions[argmax] += 1;
    }
    let wall = t0.elapsed();

    let m = server.metrics();
    println!(
        "\n{REQUESTS} requests in {:.1} ms wall: {:.0} req/s, \
         p50 {:.2} ms, p99 {:.2} ms, mean batch {:.1}",
        wall.as_secs_f64() * 1e3,
        REQUESTS as f64 / wall.as_secs_f64(),
        m.p50_ms,
        m.p99_ms,
        m.mean_batch
    );
    println!(
        "simulated accelerator time: {:.2} ms total ({:.3} ms per batch)",
        m.sim_accel_s * 1e3,
        m.sim_accel_s * 1e3 / m.batches as f64
    );
    println!("prediction histogram (10 classes): {predictions:?}");
    server.shutdown();
    Ok(())
}
