//! Deploying a *custom* network from a `.net` description file — the
//! workflow a downstream user follows for a model that is not in the zoo:
//!
//! 1. describe the layer chain in the text format (`nets/residual_tiny.net`),
//! 2. pick a target device,
//! 3. run the AutoWS DSE and compare against the vanilla baseline,
//! 4. validate the streaming schedule in the cycle-accurate simulator.
//!
//! ```sh
//! cargo run --release --example custom_network [path/to/model.net] [device]
//! ```

use autows::device::Device;
use autows::dse::{self, DseConfig};
use autows::ir::{parse_network, serialize_network, Quant};
use autows::schedule::BurstSchedule;
use autows::sim::{simulate, SimConfig};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let path = args.first().map(String::as_str).unwrap_or("nets/residual_tiny.net");
    let device = args.get(1).map(String::as_str).unwrap_or("zedboard");

    let text = std::fs::read_to_string(path)?;
    let net = parse_network(&text, Quant::W8A8).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
    let dev = Device::by_name(device).ok_or_else(|| anyhow::anyhow!("unknown device {device}"))?;

    let s = net.stats();
    println!(
        "{}: {} layers ({} with weights), {:.2}K params, {:.2}M MACs",
        net.name,
        s.total_layers,
        s.weight_layers,
        s.params as f64 / 1e3,
        s.macs as f64 / 1e6
    );

    // Round-trip sanity: the serializer regenerates an equivalent description.
    let reparsed = parse_network(&serialize_network(&net), Quant::W8A8).expect("round-trip");
    assert_eq!(reparsed.stats(), s, "serializer must preserve the model");

    for (label, cfg) in [("AutoWS", DseConfig::default()), ("vanilla", DseConfig::vanilla())] {
        match dse::run(&net, &dev, &cfg) {
            None => println!("{label:>8}: INFEASIBLE on {}", dev.name),
            Some(r) => {
                let sim = simulate(&r.design, &dev, &SimConfig::default());
                let sched = BurstSchedule::from_design(&r.design, &dev, 1);
                println!(
                    "{label:>8}: θ={:>9.1} fps  latency={:.3} ms  mem {:>3.0}%  \
                     {} streaming layers (balanced={})  sim stalls {:.1} us",
                    r.throughput,
                    r.latency_ms,
                    r.area.mem_utilization(&dev) * 100.0,
                    sched.entries.len(),
                    sched.balanced(),
                    sim.total_stall_s * 1e6,
                );
            }
        }
    }
    Ok(())
}
