//! Deploying a *custom* network from a `.net` description file — the
//! workflow a downstream user follows for a model that is not in the zoo:
//!
//! 1. describe the layer chain in the text format (`nets/residual_tiny.net`),
//! 2. pick a target device,
//! 3. run the AutoWS DSE and compare against the vanilla baseline,
//! 4. validate the streaming schedule in the cycle-accurate simulator.
//!
//! All through `autows::pipeline`: `Deployment::for_net_file` ingests the
//! description, `.explore()` runs Algorithm 1, `.schedule()` derives the
//! burst schedule.
//!
//! ```sh
//! cargo run --release --example custom_network [path/to/model.net] [device]
//! ```

use autows::dse::DseConfig;
use autows::ir::{parse_network, serialize_network, Quant};
use autows::pipeline::Deployment;
use autows::sim::SimConfig;

fn main() -> Result<(), autows::Error> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let path = args.first().map(String::as_str).unwrap_or("nets/residual_tiny.net");
    let device = args.get(1).map(String::as_str).unwrap_or("zedboard");

    let plan = Deployment::for_net_file(path).quant(Quant::W8A8).on_device(device)?;
    let s = plan.network().stats();
    println!(
        "{}: {} layers ({} with weights), {:.2}K params, {:.2}M MACs",
        plan.network().name,
        s.total_layers,
        s.weight_layers,
        s.params as f64 / 1e3,
        s.macs as f64 / 1e6
    );

    // Round-trip sanity: the serializer regenerates an equivalent description.
    let reparsed =
        parse_network(&serialize_network(plan.network()), Quant::W8A8).expect("round-trip");
    assert_eq!(reparsed.stats(), s, "serializer must preserve the model");

    for (label, cfg) in [("AutoWS", DseConfig::default()), ("vanilla", DseConfig::vanilla())] {
        match plan.clone().explore(&cfg) {
            Err(e) if e.is_infeasible() => {
                println!("{label:>8}: INFEASIBLE on {}", plan.device().name)
            }
            Err(e) => return Err(e),
            Ok(explored) => {
                let r = explored.result().clone();
                let mem = r.area.mem_utilization(explored.device());
                let sched = explored.schedule();
                let sim = sched.simulate(&SimConfig::default());
                println!(
                    "{label:>8}: θ={:>9.1} fps  latency={:.3} ms  mem {:>3.0}%  \
                     {} streaming layers (balanced={})  sim stalls {:.1} us",
                    r.throughput,
                    r.latency_ms,
                    mem * 100.0,
                    sched.burst_schedule().entries.len(),
                    sched.burst_schedule().balanced(),
                    sim.total_stall_s * 1e6,
                );
            }
        }
    }
    Ok(())
}
