//! The paper's §V-C case study: ResNet18 on ZCU102 — regenerates Fig. 6
//! (memory/performance trade-off), Table III (resource breakdown) and
//! Fig. 7 (per-layer allocation) in one run.
//!
//! ```sh
//! cargo run --release --example resnet18_zcu102
//! ```

use autows::report;

fn main() {
    println!("{}", report::fig6());
    println!("{}", report::table3());
    println!("{}", report::fig7());
}
