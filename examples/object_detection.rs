//! The paper's §V-D workload: YOLOv5n object detection on ZCU102, with the
//! per-layer design dump showing where the PAN head's weights end up.
//! The AutoWS design point comes from the `autows::pipeline` chain.
//!
//! ```sh
//! cargo run --release --example object_detection
//! ```

use autows::baseline::{self, sequential_latency_ms};
use autows::dse::DseConfig;
use autows::ir::Quant;
use autows::pipeline::Deployment;
use autows::sim::{simulate, SimConfig};

fn main() -> Result<(), autows::Error> {
    let plan =
        Deployment::for_model("yolov5n").quant(Quant::W8A8).on_device("zcu102")?;
    let s = plan.network().stats();
    println!(
        "YOLOv5n @640x640 W8A8: {:.2}M params, {:.1}G MACs, {} layers ({} with weights)\n",
        s.params as f64 / 1e6,
        s.macs as f64 / 1e9,
        s.total_layers,
        s.weight_layers
    );

    let seq = sequential_latency_ms(plan.network(), plan.device());
    let vanilla = baseline::vanilla(plan.network(), plan.device())
        .map(|r| simulate(&r.design, plan.device(), &SimConfig::default()).latency_ms);
    let autows = plan.explore(&DseConfig::default())?.schedule();
    let a_ms = autows.simulate(&SimConfig::default()).latency_ms;

    println!("layer-sequential (Vitis-AI-like): {seq:>6.1} ms   (paper: 13.7 ms)");
    match vanilla {
        Some(v) => println!("vanilla layer-pipelined:          {v:>6.1} ms   (paper:  9.5 ms)"),
        None => println!("vanilla layer-pipelined:               X"),
    }
    println!("AutoWS (this work):               {a_ms:>6.1} ms   (paper:  8.7 ms)\n");

    // top-10 largest CEs of the AutoWS design
    let design = autows.design();
    let mut layers: Vec<_> = design
        .network
        .layers
        .iter()
        .enumerate()
        .filter(|(_, l)| l.has_weights())
        .collect();
    layers.sort_by_key(|(i, _)| std::cmp::Reverse(design.area_of(*i).bram.total()));
    println!("largest CEs by BRAM:");
    for (i, l) in layers.into_iter().take(10) {
        let c = &design.cfgs[i];
        println!(
            "  {:<16} {:>4} BRAM  kp={:<2} cp={:<3} fp={:<3} off-chip {:>3.0}%",
            l.name,
            design.area_of(i).bram.total(),
            c.kp,
            c.cp,
            c.fp,
            c.frag.off_chip_ratio() * 100.0
        );
    }
    Ok(())
}
