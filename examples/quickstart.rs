//! Quickstart: map a DNN onto an FPGA with AutoWS in ~20 lines.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use autows::device::Device;
use autows::dse::{self, DseConfig};
use autows::ir::Quant;
use autows::models;
use autows::schedule::BurstSchedule;
use autows::sim::{simulate, SimConfig};

fn main() {
    // 1. pick a network and a target device
    let network = models::resnet18(Quant::W4A5);
    let device = Device::zcu102();
    println!(
        "{}: {:.1}M params, {:.1}G MACs -> {} ({:.1} MB on-chip, {:.0} Gbps)",
        network.name,
        network.stats().params as f64 / 1e6,
        network.stats().macs as f64 / 1e9,
        device.name,
        device.mem_mbytes(),
        device.bandwidth_gbps()
    );

    // 2. run the greedy DSE (paper Algorithm 1)
    let result = dse::run(&network, &device, &DseConfig::default())
        .expect("AutoWS always finds a feasible design when streaming is allowed");
    println!(
        "design: {:.1} fps, {:.2} ms latency, {} DSPs, {} BRAMs ({:.0}% of device memory)",
        result.throughput,
        result.latency_ms,
        result.area.dsp,
        result.area.bram.total(),
        result.area.mem_utilization(&device) * 100.0
    );

    // 3. inspect the weight-streaming schedule (paper §IV-B)
    let schedule = BurstSchedule::from_design(&result.design, &device, 1);
    println!(
        "streaming {} layers, write bursts balanced: {}, DMA utilization {:.0}%",
        schedule.entries.len(),
        schedule.balanced(),
        schedule.dma_utilization() * 100.0
    );

    // 4. validate with the cycle-accurate simulator
    let sim = simulate(&result.design, &device, &SimConfig::default());
    println!(
        "simulated: {:.2} ms ({} DMA events, {:.1} us stalled, DMA busy {:.0}%)",
        sim.latency_ms,
        sim.events,
        sim.total_stall_s * 1e6,
        sim.dma_busy_frac * 100.0
    );
}
