//! Quickstart: map a DNN onto an FPGA with the `autows::pipeline` staged
//! builder — model → device → DSE → schedule → simulate, in ~10 lines.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use autows::dse::DseConfig;
use autows::ir::Quant;
use autows::pipeline::Deployment;
use autows::sim::SimConfig;

fn main() -> Result<(), autows::Error> {
    // model → device → DSE (paper Algorithm 1) → burst schedule (Eq. 8-10);
    // each stage is a distinct type, so skipping one is a compile error.
    let scheduled = Deployment::for_model("resnet18")
        .quant(Quant::W4A5)
        .on_device("zcu102")?
        .explore(&DseConfig::default())?
        .schedule();

    // the deployment report: DSE metrics, schedule health, per-layer table
    print!("{}", scheduled.report());

    // validate with the cycle-accurate simulator
    let sim = scheduled.simulate(&SimConfig::default());
    println!(
        "simulated: {:.2} ms ({} DMA events, {:.1} us stalled, DMA busy {:.0}%)",
        sim.latency_ms,
        sim.events,
        sim.total_stall_s * 1e6,
        sim.dma_busy_frac * 100.0
    );
    Ok(())
}
