//! Fleet placement: three models, three boards, one router.
//!
//! `Deployment::fleet` hands the whole model list and the whole device pool
//! to the placement search (`dse::fleet`): each model may be placed solo on
//! one board, sharded across several (`dse::partition`), or co-located with
//! others on one (`dse::colocate`), under either objective —
//! `MaxAggregateThroughput` packs for summed fps, `MinDevicesAtSlo` opens
//! boards only when the p99 proxy demands it. The terminal `.serve` fronts
//! every per-device serving stack behind ONE `Router`: submit by model
//! name, least-outstanding-requests replica choice, per-model metrics.
//!
//! The load side uses `ArrivalSchedule::mixed` — one seed-deterministic
//! Poisson superposition over all models, so the multi-model arrival
//! ordering is reproducible across runs.
//!
//! ```sh
//! cargo run --release --example fleet_deploy
//! ```

use std::time::Duration;

use autows::coordinator::{
    run_open_loop_mixed, ArrivalSchedule, BatchPolicy, MixedSpec, ServerOptions,
};
use autows::dse::{DseConfig, FleetObjective};
use autows::ir::Quant;
use autows::pipeline::Deployment;
use autows::Error;

fn main() -> Result<(), Error> {
    // A mixed pool: one small zc706 and two zcu102s. resnet50 is the big
    // tenant; the search decides who shards, who shares, who rides solo.
    let scheduled = Deployment::fleet(
        [
            Deployment::for_model("resnet50").quant(Quant::W8A8),
            Deployment::for_model("resnet18").quant(Quant::W4A5),
            Deployment::for_model("squeezenet").quant(Quant::W8A8),
        ],
        &["zc706", "zcu102", "zcu102"],
    )?
    .with_objective(FleetObjective::MaxAggregateThroughput)
    .explore(&DseConfig::default())?
    .schedule();
    print!("{}", scheduled.report());

    // One router over every placement's serving stack: solo/sharded models
    // get a Server (sharded ones behind a ChainedEngine spanning their
    // boards), co-located groups a ModelRegistry on their shared board.
    let router = scheduled.serve(
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) },
        ServerOptions { queue_cap: 256, workers: 2, dispatch_shards: 0 },
    )?;
    println!("\nrouter: models={:?}, endpoints={:?}", router.models(), router.endpoint_labels());

    // A 60/30/10 traffic mix over the fleet, one deterministic arrival
    // process for all three models (seed 42).
    let mix = [
        MixedSpec { model: "resnet18".to_string(), rate_rps: 600.0 },
        MixedSpec { model: "squeezenet".to_string(), rate_rps: 300.0 },
        MixedSpec { model: "resnet50".to_string(), rate_rps: 100.0 },
    ];
    let schedule = ArrivalSchedule::mixed(256, &mix, 42);
    let res = run_open_loop_mixed(&schedule, |model| {
        let input_len = scheduled.input_len(model).expect("model from the plan");
        router.submit(model, vec![0.5; input_len])
    });
    println!(
        "\nmixed load: offered {:.0} rps, achieved {:.0} rps, p50 {:.2} ms, p99 {:.2} ms, rejected {}",
        res.offered_rps, res.achieved_rps, res.p50_ms, res.p99_ms, res.rejected
    );

    // the router rolls metrics up per model, whatever the placement shape
    for model in router.models() {
        let m = router.model_metrics(&model).expect("routed above");
        println!(
            "{model:<12} {} requests in {} batches (mean batch {:.1}), p99 {:.2} ms",
            m.requests, m.batches, m.mean_batch, m.p99_ms
        );
    }
    router.shutdown();
    Ok(())
}
