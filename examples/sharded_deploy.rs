//! Sharded deployment: split one network across a chain of FPGAs.
//!
//! A single device caps the deployable model size even with weights
//! streaming; `Deployment::on_devices` shards the layer pipeline across
//! several devices joined by streaming links. The cut-point search balances
//! the per-partition bottlenecks against the link rates, each partition
//! gets its own DMA burst schedule, and the whole chain serves behind one
//! coordinator.
//!
//! ```sh
//! cargo run --release --example sharded_deploy
//! ```

use autows::coordinator::{BatchPolicy, ServerOptions};
use autows::dse::DseConfig;
use autows::ir::Quant;
use autows::pipeline::Deployment;
use autows::sim::SimConfig;

fn main() -> Result<(), autows::Error> {
    // ResNet50 across two ZCU102s: the search picks the cut, each partition
    // runs the greedy DSE on its own device.
    let sharded = Deployment::for_model("resnet50")
        .quant(Quant::W4A5)
        .on_devices(&["zcu102", "zcu102"])?
        .explore(&DseConfig::default())?
        .schedule();
    print!("{}", sharded.report());

    // validate the chain: per-partition event simulation + the link model
    let sim = sharded.simulate(&SimConfig { batch: 8, ..Default::default() });
    println!(
        "simulated (batch=8): {:.2} ms makespan, {:.1} us stalled, \
         steady period {:.2} us, bottleneck {:?}",
        sim.makespan_s * 1e3,
        sim.total_stall_s * 1e6,
        sim.steady_period_s * 1e6,
        sim.bottleneck
    );

    // one Server, chained engines: batching and metrics work unchanged
    let server = sharded.serve(BatchPolicy::default(), ServerOptions::default())?;
    for i in 0..16 {
        let input = vec![(i as f32) / 16.0; sharded.input_len()];
        server.infer(input).expect("chain serves");
    }
    let m = server.metrics();
    println!(
        "served {} requests in {} batches (mean batch {:.1}, p50 {:.2} ms)",
        m.requests, m.batches, m.mean_batch, m.p50_ms
    );
    server.shutdown();
    Ok(())
}
