//! Sweep every model over every device (the full Table II grid plus the
//! cells the paper leaves out) — useful for scoping a deployment.
//!
//! The grid cells are independent, so they are fanned across cores with
//! `autows::dse::parallel_cases`; rows print in the same order as the
//! sequential sweep.
//!
//! ```sh
//! cargo run --release --example device_sweep [w4a4|w4a5|w8a8]
//! ```

use autows::baseline::{self, sequential_latency_ms};
use autows::device::Device;
use autows::dse::{self, parallel_cases, DseConfig};
use autows::ir::Quant;
use autows::models;
use autows::sim::{simulate, SimConfig};

struct Row {
    model: &'static str,
    device: String,
    seq_ms: f64,
    vanilla_ms: Option<f64>,
    autows_ms: Option<f64>,
    offchip_pct: f64,
    dma_pct: f64,
}

fn main() {
    let quant = match std::env::args().nth(1).as_deref() {
        Some("w4a4") => Quant::W4A4,
        Some("w8a8") => Quant::W8A8,
        _ => Quant::W4A5,
    };
    println!("quant = {quant}\n");
    println!(
        "{:<13}{:<11}{:>10}{:>10}{:>10}{:>9}{:>8}",
        "network", "device", "seq ms", "van ms", "AutoWS", "off-ch%", "DMA%"
    );

    let models_list = ["mobilenetv2", "resnet18", "resnet50", "yolov5n"];
    let cases: Vec<(&'static str, Device)> = models_list
        .iter()
        .flat_map(|&m| Device::all().into_iter().map(move |d| (m, d)))
        .collect();

    let rows: Vec<Row> = parallel_cases(&cases, |_, &(model, ref dev)| {
        let net = models::by_name(model, quant).unwrap();
        let seq_ms = sequential_latency_ms(&net, dev);
        let vanilla_ms = baseline::vanilla(&net, dev)
            .map(|r| simulate(&r.design, dev, &SimConfig::default()).latency_ms);
        let (autows_ms, offchip_pct, dma_pct) = match dse::run(&net, dev, &DseConfig::default()) {
            None => (None, 0.0, 0.0),
            Some(r) => {
                let sim = simulate(&r.design, dev, &SimConfig::default());
                let total: u64 = net.layers.iter().map(|l| l.weight_bits()).sum();
                let off: f64 = r
                    .design
                    .cfgs
                    .iter()
                    .zip(&net.layers)
                    .map(|(c, l)| c.frag.off_chip_ratio() * l.weight_bits() as f64)
                    .sum::<f64>()
                    / total as f64;
                let sched = autows::schedule::BurstSchedule::from_design(&r.design, dev, 1);
                (Some(sim.latency_ms), off * 100.0, sched.dma_utilization() * 100.0)
            }
        };
        Row {
            model,
            device: dev.name.to_string(),
            seq_ms,
            vanilla_ms,
            autows_ms,
            offchip_pct,
            dma_pct,
        }
    });

    let fmt = |v: Option<f64>| v.map_or("X".into(), |x| format!("{x:.1}"));
    let mut last_model = "";
    for row in &rows {
        if !last_model.is_empty() && row.model != last_model {
            println!();
        }
        last_model = row.model;
        println!(
            "{:<13}{:<11}{:>10.1}{:>10}{:>10}{:>8.1}%{:>7.0}%",
            row.model,
            row.device,
            row.seq_ms,
            fmt(row.vanilla_ms),
            fmt(row.autows_ms),
            row.offchip_pct,
            row.dma_pct
        );
    }
    println!();
}
