//! Sweep every model over every device (the full Table II grid plus the
//! cells the paper leaves out) — useful for scoping a deployment.
//!
//! The grid cells are independent, so they are fanned across cores with
//! `autows::pipeline::sweep::parallel_plans`; every cell explores through
//! the shared design cache, and rows print in the same order as the
//! sequential sweep.
//!
//! ```sh
//! cargo run --release --example device_sweep [w4a4|w4a5|w8a8]
//! ```

use autows::baseline::{self, sequential_latency_ms};
use autows::device::Device;
use autows::dse::DseConfig;
use autows::ir::Quant;
use autows::pipeline::{sweep::parallel_plans, Deployment, Planned};
use autows::sim::{simulate, SimConfig};

struct Row {
    model: String,
    device: String,
    seq_ms: f64,
    vanilla_ms: Option<f64>,
    autows_ms: Option<f64>,
    offchip_pct: f64,
    dma_pct: f64,
}

fn main() -> Result<(), autows::Error> {
    let quant = match std::env::args().nth(1).as_deref() {
        Some("w4a4") => Quant::W4A4,
        Some("w8a8") => Quant::W8A8,
        _ => Quant::W4A5,
    };
    println!("quant = {quant}\n");
    println!(
        "{:<13}{:<11}{:>10}{:>10}{:>10}{:>9}{:>8}",
        "network", "device", "seq ms", "van ms", "AutoWS", "off-ch%", "DMA%"
    );

    // resolve the whole grid up front: name typos fail here, not mid-sweep
    let mut plans: Vec<Planned> = Vec::new();
    for model in ["mobilenetv2", "resnet18", "resnet50", "yolov5n"] {
        for dev in Device::all() {
            plans.push(Deployment::for_model(model).quant(quant).on_device(dev)?);
        }
    }

    let rows: Vec<Row> = parallel_plans(&plans, |_, plan| {
        let (net, dev) = (plan.network(), plan.device());
        let seq_ms = sequential_latency_ms(net, dev);
        let vanilla_ms = baseline::vanilla(net, dev)
            .map(|r| simulate(&r.design, dev, &SimConfig::default()).latency_ms);
        let (autows_ms, offchip_pct, dma_pct) =
            match plan.clone().explore(&DseConfig::default()) {
                Err(_) => (None, 0.0, 0.0),
                Ok(explored) => {
                    let off = explored.design().offchip_weight_frac();
                    let sched = explored.schedule();
                    let sim = sched.simulate(&SimConfig::default());
                    (
                        Some(sim.latency_ms),
                        off * 100.0,
                        sched.burst_schedule().dma_utilization() * 100.0,
                    )
                }
            };
        Row {
            model: net.name.clone(),
            device: dev.name.to_string(),
            seq_ms,
            vanilla_ms,
            autows_ms,
            offchip_pct,
            dma_pct,
        }
    });

    let fmt = |v: Option<f64>| v.map_or("X".into(), |x| format!("{x:.1}"));
    let mut last_model = String::new();
    for row in &rows {
        if !last_model.is_empty() && row.model != last_model {
            println!();
        }
        last_model = row.model.clone();
        println!(
            "{:<13}{:<11}{:>10.1}{:>10}{:>10}{:>8.1}%{:>7.0}%",
            row.model,
            row.device,
            row.seq_ms,
            fmt(row.vanilla_ms),
            fmt(row.autows_ms),
            row.offchip_pct,
            row.dma_pct
        );
    }
    println!();
    Ok(())
}
