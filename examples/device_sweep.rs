//! Sweep every model over every device (the full Table II grid plus the
//! cells the paper leaves out) — useful for scoping a deployment.
//!
//! ```sh
//! cargo run --release --example device_sweep [w4a4|w4a5|w8a8]
//! ```

use autows::baseline::{self, sequential_latency_ms};
use autows::device::Device;
use autows::dse::{self, DseConfig};
use autows::ir::Quant;
use autows::models;
use autows::sim::{simulate, SimConfig};

fn main() {
    let quant = match std::env::args().nth(1).as_deref() {
        Some("w4a4") => Quant::W4A4,
        Some("w8a8") => Quant::W8A8,
        _ => Quant::W4A5,
    };
    println!("quant = {quant}\n");
    println!(
        "{:<13}{:<11}{:>10}{:>10}{:>10}{:>9}{:>8}",
        "network", "device", "seq ms", "van ms", "AutoWS", "off-ch%", "DMA%"
    );
    for model in ["mobilenetv2", "resnet18", "resnet50", "yolov5n"] {
        let net = models::by_name(model, quant).unwrap();
        for dev in Device::all() {
            let seq = sequential_latency_ms(&net, &dev);
            let van = baseline::vanilla(&net, &dev)
                .map(|r| simulate(&r.design, &dev, &SimConfig::default()).latency_ms);
            let (autows, off, dma) = match dse::run(&net, &dev, &DseConfig::default()) {
                None => (None, 0.0, 0.0),
                Some(r) => {
                    let sim = simulate(&r.design, &dev, &SimConfig::default());
                    let total: u64 = net.layers.iter().map(|l| l.weight_bits()).sum();
                    let off: f64 = r
                        .design
                        .cfgs
                        .iter()
                        .zip(&net.layers)
                        .map(|(c, l)| c.frag.off_chip_ratio() * l.weight_bits() as f64)
                        .sum::<f64>()
                        / total as f64;
                    let sched =
                        autows::schedule::BurstSchedule::from_design(&r.design, &dev, 1);
                    (Some(sim.latency_ms), off * 100.0, sched.dma_utilization() * 100.0)
                }
            };
            let fmt = |v: Option<f64>| v.map_or("X".into(), |x| format!("{x:.1}"));
            println!(
                "{:<13}{:<11}{:>10.1}{:>10}{:>10}{:>8.1}%{:>7.0}%",
                model,
                dev.name,
                seq,
                fmt(van),
                fmt(autows),
                off,
                dma
            );
        }
        println!();
    }
}
