//! Multi-tenant serving under open-loop load: the co-location scenario.
//!
//! Two networks (toy CNN + SqueezeNet) are planned onto ONE zcu102 by the
//! joint budget search (`Deployment::colocate`): the device's area and DMA
//! bandwidth are split into per-tenant shares (seeded by weight footprint,
//! rebalanced toward the worst bottleneck), each tenant gets its own burst
//! schedule against its bandwidth slice, and `.serve` registers every
//! tenant in one `ModelRegistry` — its own batcher, admission cap and
//! metrics per tenant. A deterministic Poisson load generator then sweeps
//! the offered rate per tenant and prints the latency-vs-load curve — the
//! knee where the (simulated) shared accelerator saturates is the
//! serving-side counterpart of the paper's throughput numbers.
//!
//! ```sh
//! cargo run --release --example multi_model_serve
//! ```

use std::time::Duration;

use autows::coordinator::{
    run_open_loop, ArrivalSchedule, BatchPolicy, Priority, ServerOptions,
};
use autows::dse::DseConfig;
use autows::ir::Quant;
use autows::pipeline::Deployment;
use autows::Error;

fn main() -> Result<(), Error> {
    // One joint plan instead of two independent full-device plans: the
    // tenants share the zcu102, and the report shows who got which share.
    let scheduled = Deployment::colocate([
        Deployment::for_model("toy").quant(Quant::W8A8),
        Deployment::for_model("squeezenet").quant(Quant::W8A8),
    ])
    .on_device("zcu102")?
    .explore(&DseConfig::default())?
    .schedule();
    print!("{}", scheduled.report());

    // two pool workers per tenant: the SimOnly engines clone cheaply, and
    // the registry's batching/metrics are unchanged by the fan-out
    let registry = scheduled.serve(
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) },
        ServerOptions { queue_cap: 256, workers: 2, dispatch_shards: 0 },
    )?;

    println!("\nopen-loop latency vs offered load (64 Poisson arrivals per point):");
    println!("model           offered(rps)  achieved  p50(ms)  p95(ms)  p99(ms)  rejected");
    for name in scheduled.tenant_names() {
        let input_len = scheduled.input_len(name).expect("tenant from the plan");
        for rate in [200.0, 1000.0, 5000.0] {
            let schedule = ArrivalSchedule::poisson(64, rate, 42);
            let res = run_open_loop(&schedule, || {
                registry.submit(name, vec![0.5; input_len], Priority::Normal)
            });
            println!(
                "{name:<15} {:>11.0} {:>9.0} {:>8.2} {:>8.2} {:>8.2} {:>9}",
                res.offered_rps, res.achieved_rps, res.p50_ms, res.p95_ms, res.p99_ms, res.rejected
            );
        }
    }

    // per-tenant metrics are independent
    for name in scheduled.tenant_names() {
        let m = registry.metrics(name).expect("tenant from the plan");
        println!(
            "{name}: served {} requests in {} batches (mean batch {:.1})",
            m.requests, m.batches, m.mean_batch
        );
    }
    registry.shutdown();
    Ok(())
}
