//! Multi-model serving under open-loop load: the deployment scenario.
//!
//! Two accelerator designs (toy CNN + SqueezeNet) are explored through the
//! `autows::pipeline` chain and registered in the model registry, each with
//! its own DSE schedule, batcher and admission cap. A deterministic Poisson
//! load generator sweeps the offered rate and prints the latency-vs-load
//! curve per model — the knee where the (simulated) accelerator saturates
//! is the serving-side counterpart of the paper's throughput numbers.
//!
//! ```sh
//! cargo run --release --example multi_model_serve
//! ```

use std::time::Duration;

use autows::coordinator::{
    run_open_loop, ArrivalSchedule, BatchPolicy, ModelEntry, ModelRegistry, Priority,
    ServerOptions, SimOnlyEngine,
};
use autows::dse::DseConfig;
use autows::ir::Quant;
use autows::pipeline::Deployment;
use autows::Error;

fn main() -> Result<(), Error> {
    let mut reg = ModelRegistry::new();

    for (alias, model, q) in
        [("toy-w8", "toy", Quant::W8A8), ("squeezenet-w8", "squeezenet", Quant::W8A8)]
    {
        let explored = Deployment::for_model(model)
            .quant(q)
            .on_device("zcu102")?
            .explore(&DseConfig::default())?;
        let r = explored.result();
        println!(
            "{alias}: θ={:.0} fps, {} streaming layers, mem {:.0}%",
            r.throughput,
            r.design.streaming_count(),
            r.area.mem_utilization(explored.device()) * 100.0
        );
        let (c, h, w) = explored.design().network.input_shape;
        let input_len = (c * h * w) as usize;
        let engine = SimOnlyEngine {
            design: explored.design().clone(),
            device: explored.device().clone(),
            input_len,
            output_len: 10,
        };
        // registry failures are typed `autows::Error` now — `?` just works
        reg.register(
            ModelEntry {
                name: alias.into(),
                input_len,
                policy: BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) },
                options: ServerOptions { queue_cap: 256 },
            },
            move || Ok(Box::new(engine) as _),
        )?;
    }

    println!("\nopen-loop latency vs offered load (64 Poisson arrivals per point):");
    println!("model           offered(rps)  achieved  p50(ms)  p95(ms)  p99(ms)  rejected");
    for alias in ["toy-w8", "squeezenet-w8"] {
        let input_len = reg.entry(alias).unwrap().input_len;
        for rate in [200.0, 1000.0, 5000.0] {
            let schedule = ArrivalSchedule::poisson(64, rate, 42);
            let res = run_open_loop(&schedule, || {
                reg.submit(alias, vec![0.5; input_len], Priority::Normal)
            });
            println!(
                "{alias:<15} {:>11.0} {:>9.0} {:>8.2} {:>8.2} {:>8.2} {:>9}",
                res.offered_rps, res.achieved_rps, res.p50_ms, res.p95_ms, res.p99_ms, res.rejected
            );
        }
    }

    // per-model metrics are independent
    for alias in ["toy-w8", "squeezenet-w8"] {
        let m = reg.metrics(alias).unwrap();
        println!(
            "{alias}: served {} requests in {} batches (mean batch {:.1})",
            m.requests, m.batches, m.mean_batch
        );
    }
    reg.shutdown();
    Ok(())
}
